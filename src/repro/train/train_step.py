"""Train step: loss -> grads (with microbatch accumulation) -> update.

Key levers (all config-driven, all measured in EXPERIMENTS.md §Perf):
  * ``cfg.grad_accum``       — microbatches per step (lax.scan over
    microbatches keeps peak activation memory ~1/grad_accum);
  * ``cfg.grad_accum_dtype`` — f32 (default) or bf16 accumulation; bf16
    halves both the accumulator memory and the DP all-reduce bytes
    (gradient compression at the collective level);
  * ``cfg.remat``            — activation checkpointing policy (in model);
  * sharding constraints re-applied to the gradient tree so the XLA SPMD
    partitioner keeps grads co-sharded with params (FSDP reduce-scatter).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from ..configs.base import ModelConfig
from ..models.model_api import Model
from .optimizer import OptimizerConfig, apply_updates, init_opt_state


def _split_microbatches(batch: Dict[str, jnp.ndarray], n: int) -> Dict[str, jnp.ndarray]:
    """(B, ...) -> (n, B/n, ...) for every array in the batch."""

    def split(x):
        b = x.shape[0]
        assert b % n == 0, f"global batch {b} not divisible by grad_accum {n}"
        return x.reshape((n, b // n) + x.shape[1:])

    return {k: split(v) for k, v in batch.items()}


def make_train_step(
    model: Model,
    oc: OptimizerConfig,
    mesh: Optional[Mesh] = None,
) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""
    cfg = model.cfg
    accum_dt = jnp.dtype(cfg.grad_accum_dtype)

    def loss_fn(params, microbatch):
        loss, metrics = model.loss(params, microbatch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def constrain_like_params(tree):
        if mesh is None:
            return tree
        specs = model.pspecs(mesh)
        return jax.tree_util.tree_map(
            lambda x, s: jax.lax.with_sharding_constraint(x, NamedSharding(mesh, s)),
            tree, specs,
        )

    def train_step(params, opt_state, batch):
        n = cfg.grad_accum
        if n <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            micro = _split_microbatches(batch, n)
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, accum_dt), params
            )
            zeros = constrain_like_params(zeros)

            def body(carry, mb):
                acc, loss_acc = carry
                (loss, _), grads = grad_fn(params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(accum_dt), acc, grads
                )
                acc = constrain_like_params(acc)
                return (acc, loss_acc + loss), None

            (grads, loss_sum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), micro
            )
            grads = jax.tree_util.tree_map(lambda g: (g / n).astype(accum_dt), grads)
            loss = loss_sum / n
            metrics = {"loss": loss}

        grads = constrain_like_params(grads)
        new_params, new_opt, opt_metrics = apply_updates(params, grads, opt_state, oc)
        new_params = constrain_like_params(new_params)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def init_train_state(model: Model, oc: OptimizerConfig, rng: jax.Array):
    params = model.init(rng)
    opt_state = init_opt_state(params, oc)
    return params, opt_state
