"""Sharded, atomic, async checkpointing (hand-rolled; no orbax here).

Layout: ``<dir>/step_<N>/`` containing one ``shard_<host>.npz`` per host
(single host in this container; the format carries host count so a
restore on a different host topology reshards through device_put) plus a
``manifest.json`` with the tree structure, shapes, dtypes and step.

Guarantees:
  * atomic publish — data is written to ``step_<N>.tmp`` and renamed;
    a crash mid-write can never corrupt the latest checkpoint;
  * async save — ``save_async`` snapshots params to host memory
    synchronously (cheap) and writes on a background thread, overlapping
    checkpoint I/O with the next training steps (the paper's lesson of
    keeping slow I/O off the critical path);
  * restore with resharding — arrays are device_put against the target
    NamedSharding, so a checkpoint from one mesh restores onto another
    (elastic restart).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding


def _flatten_with_paths(tree: Any) -> Dict[str, Any]:
    flat = {}

    def walk(node, path):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(v, path + (str(k),))
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(v, path + (str(i),))
        else:
            flat["/".join(path)] = node

    walk(tree, ())
    return flat


def _unflatten_into(template: Any, flat: Dict[str, Any]) -> Any:
    def walk(node, path):
        if isinstance(node, dict):
            return {k: walk(v, path + (str(k),)) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v, path + (str(i),)) for i, v in enumerate(node))
        return flat["/".join(path)]

    return walk(template, ())


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3) -> None:
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._async_thread: Optional[threading.Thread] = None
        self._async_err: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def _write(self, step: int, host_arrays: Dict[str, np.ndarray], extra: dict) -> str:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "shard_0.npz"), **host_arrays)
        manifest = {
            "step": step,
            "n_hosts": 1,
            "time": time.time(),
            "keys": sorted(host_arrays),
            "extra": extra,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)   # atomic publish
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    def save(self, step: int, tree: Any, extra: Optional[dict] = None) -> str:
        flat = _flatten_with_paths(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}
        return self._write(step, host, extra or {})

    def save_async(self, step: int, tree: Any, extra: Optional[dict] = None) -> None:
        """Snapshot to host synchronously, write on a background thread."""
        self.wait()   # one outstanding async save at a time
        flat = _flatten_with_paths(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}   # device->host copy now

        def _work():
            try:
                self._write(step, host, extra or {})
            except BaseException as exc:  # noqa: BLE001
                self._async_err = exc

        self._async_thread = threading.Thread(target=_work, daemon=True, name="ckpt-writer")
        self._async_thread.start()

    def wait(self) -> None:
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None
        if self._async_err is not None:
            err, self._async_err = self._async_err, None
            raise RuntimeError("async checkpoint failed") from err

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        step: int,
        template: Any,
        mesh: Optional[Mesh] = None,
        pspecs: Optional[Any] = None,
    ) -> Tuple[Any, dict]:
        """Restore into the structure of ``template``; reshard if mesh given."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "shard_0.npz"))
        flat = {k: data[k] for k in data.files}

        if mesh is not None and pspecs is not None:
            spec_flat = _flatten_with_paths(pspecs)
            flat = {
                k: jax.device_put(v, NamedSharding(mesh, spec_flat[k]))
                for k, v in flat.items()
            }
        else:
            flat = {k: jnp.asarray(v) for k, v in flat.items()}
        return _unflatten_into(template, flat), manifest.get("extra", {})
