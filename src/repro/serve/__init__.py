"""Serving substrate: jitted decode step + continuous-batching engine."""

from .decode import make_serve_step, make_dryrun_serve_step
from .engine import ServingEngine, Request, EngineStats
