"""serve_step: the jitted single-token decode used by the engine & dry run."""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from ..models.model_api import Model


def make_serve_step(model: Model, greedy: bool = True, temperature: float = 1.0) -> Callable:
    """Returns serve_step(params, cache, tokens, lengths, rng) ->
    (next_tokens (B,1), logits (B,1,V), cache)."""

    def serve_step(params, cache, tokens, lengths, rng):
        logits, cache = model.decode_step(params, cache, tokens, lengths)
        if greedy:
            nxt = jnp.argmax(logits[:, -1], axis=-1)
        else:
            nxt = jax.random.categorical(rng, logits[:, -1] / temperature, axis=-1)
        return nxt[:, None].astype(jnp.int32), logits, cache

    return serve_step


def make_dryrun_serve_step(model: Model) -> Callable:
    """Decode step shaped for the dry run: cache passes through as an
    explicit arg so the compiled program owns no state."""

    def serve_step(params, cache, tokens, lengths):
        logits, cache = model.decode_step(params, cache, tokens, lengths)
        nxt = jnp.argmax(logits[:, -1], axis=-1)
        return nxt[:, None].astype(jnp.int32), cache

    return serve_step
