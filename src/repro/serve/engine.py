"""Batched serving engine with continuous batching + Colmena steering hooks.

Slots hold independent requests; each engine step decodes one token for
every active slot (synchronized step, per-slot lengths). Finished slots
(eos or max tokens) are refilled from the admission queue without
stopping the batch — continuous batching. The engine exposes callbacks
(``on_token``, ``on_finish``) that a Colmena Thinker uses for steering
(e.g. early-stopping low-value generations — the paper's "stop evaluating
low-performing candidates" multi-fidelity lesson applied to serving).
"""

from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.model_api import Model
from ..models import transformer as tmod
from .decode import make_serve_step


@dataclass
class Request:
    request_id: int
    prompt: np.ndarray                   # (P,) int32
    max_new_tokens: int = 32
    eos_token: Optional[int] = None
    # filled by the engine:
    generated: List[int] = field(default_factory=list)
    submitted_at: float = field(default_factory=time.monotonic)
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    cancelled: bool = False


@dataclass
class EngineStats:
    steps: int = 0
    tokens_generated: int = 0
    requests_finished: int = 0
    requests_cancelled: int = 0
    batch_occupancy_sum: float = 0.0

    @property
    def mean_occupancy(self) -> float:
        return self.batch_occupancy_sum / max(self.steps, 1)


class ServingEngine:
    """Continuous-batching engine over Model.decode_step (transformer
    families; prompt prefill is token-by-token for recurrent families)."""

    def __init__(
        self,
        model: Model,
        params: Any,
        n_slots: int = 4,
        max_len: int = 256,
        on_token: Optional[Callable[[Request, int], bool]] = None,
        on_finish: Optional[Callable[[Request], None]] = None,
    ) -> None:
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.on_token = on_token
        self.on_finish = on_finish
        self.stats = EngineStats()

        self._admit: "queue.Queue[Request]" = queue.Queue()
        self._slots: List[Optional[Request]] = [None] * n_slots
        self._serve = jax.jit(make_serve_step(model))
        self._cache = model.init_cache(n_slots, max_len)
        self._lengths = jnp.zeros((n_slots,), jnp.int32)
        self._tokens = jnp.zeros((n_slots, 1), jnp.int32)
        self._rng = jax.random.PRNGKey(0)
        self._decode_jit = jax.jit(model.decode_step)

    # ----------------------------------------------------------------- admit
    def submit(self, req: Request) -> None:
        self._admit.put(req)

    def _try_fill_slots(self) -> None:
        for i in range(self.n_slots):
            if self._slots[i] is not None:
                continue
            try:
                req = self._admit.get_nowait()
            except queue.Empty:
                return
            self._prefill_slot(i, req)

    def _prefill_slot(self, i: int, req: Request) -> None:
        """Feed the prompt through decode steps for slot i.

        Idle slots are unaffected: their spurious cache writes land at the
        position their *next* real token will overwrite, and their outputs
        are discarded. The last prompt token is NOT prefed — it becomes
        slot i's current input so the next engine step generates from it."""
        lengths = np.asarray(self._lengths).copy()
        lengths[i] = 0
        self._lengths = jnp.asarray(lengths)
        for tok in req.prompt[:-1]:
            tok_vec = np.asarray(self._tokens).copy()
            tok_vec[i, 0] = int(tok)
            self._tokens = jnp.asarray(tok_vec)
            _, _, self._cache = self._serve(
                self.params, self._cache, self._tokens, self._lengths, self._rng
            )
            lengths = np.asarray(self._lengths).copy()
            lengths[i] += 1
            self._lengths = jnp.asarray(lengths)
        tok_vec = np.asarray(self._tokens).copy()
        tok_vec[i, 0] = int(req.prompt[-1])
        self._tokens = jnp.asarray(tok_vec)
        self._slots[i] = req

    # ------------------------------------------------------------------ step
    def step(self) -> int:
        """One decode step for all active slots; returns #active."""
        self._try_fill_slots()
        active = [i for i, r in enumerate(self._slots) if r is not None]
        if not active:
            return 0
        self._rng, sub = jax.random.split(self._rng)
        nxt, logits, self._cache = self._serve(self.params, self._cache, self._tokens, self._lengths, sub)
        nxt_np = np.asarray(nxt)
        self._tokens = nxt
        self._lengths = self._lengths + 1

        self.stats.steps += 1
        self.stats.batch_occupancy_sum += len(active) / self.n_slots
        for i in active:
            req = self._slots[i]
            tok = int(nxt_np[i, 0])
            if req.first_token_at is None:
                req.first_token_at = time.monotonic()
            req.generated.append(tok)
            self.stats.tokens_generated += 1
            stop = False
            if self.on_token is not None:
                stop = bool(self.on_token(req, tok))
                if stop:
                    req.cancelled = True
                    self.stats.requests_cancelled += 1
            if req.eos_token is not None and tok == req.eos_token:
                stop = True
            if len(req.generated) >= req.max_new_tokens:
                stop = True
            if stop:
                req.finished_at = time.monotonic()
                self.stats.requests_finished += 1
                if self.on_finish is not None:
                    self.on_finish(req)
                self._slots[i] = None
        return len(active)

    def run_until_drained(self, max_steps: int = 10_000) -> EngineStats:
        for _ in range(max_steps):
            if self.step() == 0 and self._admit.empty():
                break
        return self.stats
