"""Unit tests: roofline HLO parsing, report generation, and the
Colmena-steered training driver (including preemption recovery)."""

import json
import os

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: pip install -e .[test]
from hypothesis import given, settings, strategies as st

from repro.launch.roofline import (
    CollectiveStats,
    RooflineReport,
    _type_bytes,
    model_flops,
    parse_collectives,
)
from repro.configs import get_config
from repro.configs.base import SHAPES


class TestHloParsing:
    def test_type_bytes(self):
        assert _type_bytes("bf16[128,4096]{1,0}") == 128 * 4096 * 2
        assert _type_bytes("f32[16]") == 64
        assert _type_bytes("(f32[2,2], bf16[4])") == 16 + 8
        assert _type_bytes("pred[8]") == 8

    def test_parse_ring_conventions(self):
        hlo = "\n".join([
            "%ag = bf16[64,64]{1,0} all-gather(%x), replica_groups=[16,16]<=[256], dimensions={0}",
            "%ar = f32[32]{0} all-reduce(%y), replica_groups=[1,256]<=[256], to_apply=%add",
            "%rs = bf16[8,8]{1,0} reduce-scatter(%z), replica_groups=[16,16]<=[256]",
            "%cp = f32[4,4]{1,0} collective-permute(%w), source_target_pairs={{0,1}}",
        ])
        stats = parse_collectives(hlo, 256)
        assert stats.counts == {"all-gather": 1, "all-reduce": 1,
                                "reduce-scatter": 1, "collective-permute": 1}
        ag = 64 * 64 * 2 * 15 / 16                 # S_out * (n-1)/n
        ar = 2 * 32 * 4 * 255 / 256                # 2S(n-1)/n
        rs = 8 * 8 * 2 * 16 * 15 / 16              # S_in (n*out) * (n-1)/n
        cp = 4 * 4 * 4
        assert stats.wire_bytes == pytest.approx(ag + ar + rs + cp)

    def test_cross_pod_detection(self):
        hlo = "%ar = f32[8]{0} all-reduce(%y), replica_groups=[1,512]<=[512]"
        stats = parse_collectives(hlo, 512, pod_size=256)
        assert stats.cross_pod_wire_bytes > 0

    def test_start_ops_counted_once(self):
        hlo = "\n".join([
            "%s = bf16[64]{0} all-reduce-start(%x), replica_groups=[1,16]<=[16]",
        ])
        stats = parse_collectives(hlo, 16)
        assert stats.counts == {"all-reduce": 1}

    @given(st.integers(1, 4096), st.integers(2, 256))
    @settings(max_examples=30, deadline=None)
    def test_wire_bytes_nonnegative_and_bounded(self, elems, group):
        hlo = f"%ag = f32[{elems}] all-gather(%x), replica_groups=[1,{group}]<=[{group}]"
        stats = parse_collectives(hlo, group)
        assert 0 <= stats.wire_bytes <= elems * 4


class TestModelFlops:
    def test_train_uses_6nd(self):
        cfg = get_config("yi-6b")
        f = model_flops(cfg, SHAPES["train_4k"])
        assert f == pytest.approx(6.0 * cfg.n_params * 256 * 4096)

    def test_moe_uses_active_params(self):
        cfg = get_config("qwen3-moe-30b-a3b")
        f = model_flops(cfg, SHAPES["train_4k"])
        assert f < 6.0 * cfg.n_params * 256 * 4096   # active << total
        assert f == pytest.approx(6.0 * cfg.n_active_params * 256 * 4096)

    def test_decode_counts_one_token_per_seq(self):
        cfg = get_config("gemma-2b")
        f = model_flops(cfg, SHAPES["decode_32k"])
        assert f == pytest.approx(2.0 * cfg.n_params * 128)


class TestRooflineReport:
    def test_bottleneck_selection(self):
        coll = CollectiveStats(wire_bytes=50e9 * 3)   # 3 s of wire
        r = RooflineReport.build(
            "a", "s", "m", 256,
            {"flops": 197e12 * 1.0, "bytes accessed": 819e9 * 2.0},
            1024, coll, model_flops_total=197e12 * 256 * 0.5,
        )
        assert r.compute_s == pytest.approx(1.0)
        assert r.memory_s == pytest.approx(2.0)
        assert r.collective_s == pytest.approx(3.0)
        assert r.bottleneck == "collective"
        assert r.useful_flops_ratio == pytest.approx(0.5)
        assert r.roofline_fraction == pytest.approx(1.0 / 3.0)


class TestTrainingDriver:
    def test_steered_training_converges(self):
        from repro.launch.train import run
        rep = run(arch="gemma-2b", steps=30, chunk=10, seq=32, batch=4, lr=3e-3)
        assert rep["steps"] >= 30
        assert rep["final_loss"] < rep["first_loss"]

    def test_preemption_recovery(self, tmp_path):
        from repro.launch.train import run
        rep = run(arch="gemma-2b", steps=40, chunk=10, seq=32, batch=4, lr=3e-3,
                  ckpt_dir=str(tmp_path), ckpt_every=10, preempt_at=20)
        assert rep["preempted"]
        assert rep["workers_replaced"] >= 1        # node replaced
        assert rep["final_loss"] < rep["first_loss"]  # and training recovered


class TestReportRendering:
    def test_roofline_table_renders(self, tmp_path):
        from repro.launch.report import load_cells, roofline_table, dryrun_table
        cell = {
            "arch": "yi-6b", "shape": "train_4k", "mesh": "pod256", "status": "ok",
            "compute_s": 1.0, "memory_s": 2.0, "collective_s": 0.5,
            "bottleneck": "memory", "peak_memory_bytes": 2**30,
            "useful_flops_ratio": 0.5, "roofline_fraction": 0.5,
            "compile_s": 1.0, "argument_bytes": 2**29, "temp_bytes": 2**29,
            "collective_counts": {"all-reduce": 3},
        }
        with open(os.path.join(tmp_path, "c.json"), "w") as f:
            json.dump(cell, f)
        cells = load_cells(str(tmp_path))
        table = roofline_table(cells, "pod256")
        assert "yi-6b" in table and "memory" in table
        table2 = dryrun_table(cells)
        assert "all-reduce:3" in table2
