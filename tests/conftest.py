import os
import sys

# Tests run on the single real CPU device — the 512-device dry run is
# exercised via subprocesses (test_dryrun_small.py), never in-process.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
