import os
import sys

# Tests run on the single real CPU device — the 512-device dry run is
# exercised via subprocesses (test_dryrun_small.py), never in-process.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


def pytest_configure(config):
    # Opt-in mini-TSan: REPRO_LOCK_SANITIZER=1 wraps every Lock/RLock/
    # Condition created by repro code so the real acquisition order is
    # recorded; pytest_sessionfinish asserts the graph stayed acyclic.
    from repro.analyze import runtime

    if runtime.install_from_env():
        config._repro_lock_sanitizer = True


def pytest_sessionfinish(session, exitstatus):
    if not getattr(session.config, "_repro_lock_sanitizer", False):
        return
    from repro.analyze import runtime

    g = runtime.graph()
    cycles = g.find_cycles()
    tr = session.config.pluginmanager.get_plugin("terminalreporter")
    if tr is not None:
        tr.write_line(
            f"repro lock sanitizer: {g.acquisitions} acquisitions, "
            f"{len(g.edges)} ordered pairs, {len(cycles)} cycle(s)"
        )
    if cycles:
        report = g.report_cycles()
        if tr is not None:
            tr.write_line(report, red=True)
        else:
            print(report, file=sys.stderr)
        # wrap_session reads session.exitstatus after this hook returns,
        # so flipping it here fails the run without an internal error.
        session.exitstatus = 1


@pytest.fixture
def rng():
    return np.random.default_rng(0)
