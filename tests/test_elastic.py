"""PoolSpec + elastic worker fleets.

Covers the resource-vocabulary redesign: PoolSpec normalization/bounds/
pickling, the remove_workers scale-down latency regression (pending
removals claimed ahead of queued tasks), WorkerPool.resize, the
heartbeat monitor's scaled-down-vs-died distinction, the ElasticScaler
grow/shrink loop with pool_resize telemetry, and the app-level
ObserveSpec.elastic wiring.
"""

import pickle
import threading
import time

import pytest

from repro.core import (
    FailureInjector,
    PoolSpec,
    ResourceCounter,
    TaskServer,
    WorkerPool,
    LocalColmenaQueues,
    normalize_pools,
)
from repro.core.result import ResourceRequest, Result
from repro.observe import ElasticPolicy, ElasticScaler, EventLog, MetricsAggregator


def _mk_result(i=0, pool="default"):
    return Result(method="m", args=(i,), resources=ResourceRequest(pool=pool))


class TestPoolSpec:
    def test_bounds_default_to_size(self):
        ps = PoolSpec("p", 3)
        assert ps.bounds() == (3, 3)
        assert not ps.elastic
        assert ps.clamp(100) == 3 and ps.clamp(0) == 3

    def test_elastic_band(self):
        ps = PoolSpec("p", 2, min_size=1, max_size=5)
        assert ps.elastic
        assert ps.clamp(100) == 5 and ps.clamp(0) == 1 and ps.clamp(3) == 3

    def test_size_outside_band_rejected(self):
        with pytest.raises(ValueError, match="outside"):
            PoolSpec("p", 9, min_size=1, max_size=4)

    def test_min_above_max_rejected(self):
        with pytest.raises(ValueError, match="min_size"):
            PoolSpec("p", 3, min_size=5, max_size=4).bounds()

    def test_normalize_accepts_every_shorthand(self):
        out = normalize_pools({"a": 3, "b": PoolSpec("b", 2, max_size=6)})
        assert out["a"].size == 3 and out["b"].max_size == 6
        seq = normalize_pools([PoolSpec("x", 1), PoolSpec("y", 2)])
        assert set(seq) == {"x", "y"}
        assert normalize_pools(None)["default"].size == 4

    def test_normalize_rejects_bad_shapes(self):
        with pytest.raises(ValueError, match="disagrees"):
            normalize_pools({"a": PoolSpec("b", 1)})
        with pytest.raises(TypeError, match="expected an int or PoolSpec"):
            normalize_pools({"a": "three"})
        with pytest.raises(TypeError, match="sequence must contain PoolSpecs"):
            normalize_pools([4])  # would otherwise become a pool named "None"

    def test_picklable_with_injector(self):
        ps = PoolSpec("p", 2, injector=FailureInjector(task_failure_rate=0.5, seed=7))
        clone = pickle.loads(pickle.dumps(ps))
        assert clone.injector.task_failure_rate == 0.5
        assert clone.injector.seed == 7
        # the rebuilt injector is functional (lock + rng restored)
        clone.injector.after_task(0)

    def test_build_spec_fields_win_over_defaults(self):
        ps = PoolSpec("p", 1, warm_capacity=0, prefetch=False)
        pool = ps.build(warm_capacity=32, prefetch=True)
        try:
            assert pool.warm_capacity == 0 and pool.prefetch_proxies is False
        finally:
            pool.shutdown()

    def test_serialization_rejects_injector(self):
        ps = PoolSpec("p", 1, injector=FailureInjector())
        with pytest.raises(ValueError, match="not serializable"):
            ps.to_dict()


class TestScaleDownLatency:
    def test_shrink_lands_ahead_of_backlog(self):
        """Regression: a shrink queued behind a deep backlog must land
        after the worker's *current* task, not after the whole backlog
        drains — and n_workers must reflect it immediately."""
        pool = WorkerPool("p", 1, warm_capacity=0)
        done = []

        def slow(x):
            time.sleep(0.15)
            return x

        try:
            for i in range(10):
                pool.submit(_mk_result(i), slow, done.append)
            time.sleep(0.05)  # worker has picked up task 0
            pool.remove_workers(1)
            # committed capacity is reported immediately, not after drain
            assert pool.n_workers == 0
            time.sleep(0.4)
            # the worker exited after its current task; backlog remains
            assert pool.queued() > 0
            assert len(done) <= 2
            assert all(not w.alive for w in pool.worker_states())
        finally:
            pool.shutdown()

    def test_scale_down_is_not_a_death(self):
        """The heartbeat monitor must not 'replace' a cleanly removed
        worker (that would silently undo every elastic shrink)."""
        queues = LocalColmenaQueues()
        pool = WorkerPool("default", 2, warm_capacity=0)
        server = TaskServer(queues, {"m": lambda x: x}, pools={"default": pool})
        try:
            pool.remove_workers(1)
            deadline = time.monotonic() + 2.0
            while pool.n_workers != 1 and time.monotonic() < deadline:
                time.sleep(0.01)
            assert pool.n_workers == 1
            server._check_heartbeats()
            assert pool.n_workers == 1
            assert server.metrics.workers_replaced == 0
        finally:
            server.stop()

    def test_add_workers_cancels_pending_removals(self):
        pool = WorkerPool("p", 2, warm_capacity=0)
        try:
            # nothing queued: workers are idle, removals claim fast
            pool.remove_workers(2)
            assert pool.n_workers == 0
            pool.add_workers(1)
            assert pool.n_workers == 1
        finally:
            pool.shutdown()

    def test_dead_worker_never_claims_a_removal(self):
        """A killed 'node' must not consume a pending removal: the shrink
        has to land on a live worker, and the dead one must stay
        registered for the heartbeat monitor's failover."""
        pool = WorkerPool("p", 2, warm_capacity=0)
        try:
            victim = pool.worker_states()[0].worker_id
            pool.kill_worker(victim)
            pool.remove_workers(1)
            deadline = time.monotonic() + 2.0
            while pool._pending_removals > 0 and time.monotonic() < deadline:
                time.sleep(0.01)
            assert pool._pending_removals == 0          # claimed by the live worker
            remaining = {w.worker_id for w in pool.worker_states()}
            assert remaining == {victim}                # dead one kept for failover
        finally:
            pool.shutdown()

    def test_over_shrink_clamped_to_live_workers(self):
        """remove_workers beyond the fleet must not leave phantom
        pending removals that eat every later grow."""
        pool = WorkerPool("p", 2, warm_capacity=0)
        done = []
        try:
            pool.remove_workers(5)                 # only 2 can ever claim
            deadline = time.monotonic() + 2.0
            while pool.n_workers != 0 and time.monotonic() < deadline:
                time.sleep(0.01)
            assert pool.n_workers == 0
            old, new = pool.resize(2)
            assert (old, new) == (0, 2)
            assert pool.n_workers == 2             # real workers, not cancelled phantoms
            pool.submit(_mk_result(1), lambda x: x, done.append)
            deadline = time.monotonic() + 2.0
            while not done and time.monotonic() < deadline:
                time.sleep(0.01)
            assert done and done[0].value == 1     # the regrown fleet executes
        finally:
            pool.shutdown()

    def test_resize_round_trip(self):
        pool = WorkerPool("p", 2, warm_capacity=0)
        try:
            old, new = pool.resize(5)
            assert (old, new) == (2, 5)
            assert pool.n_workers == 5
            old, new = pool.resize(1)
            assert (old, new) == (5, 1)
            assert pool.n_workers == 1
            assert pool.resize(1) == (1, 1)  # no-op hold
        finally:
            pool.shutdown()

    def test_removed_worker_still_completes_current_task(self):
        pool = WorkerPool("p", 1, warm_capacity=0)
        done = []
        try:
            pool.submit(_mk_result(1), lambda x: x * 2, done.append)
            time.sleep(0.05)
            pool.remove_workers(1)
            deadline = time.monotonic() + 2.0
            while not done and time.monotonic() < deadline:
                time.sleep(0.01)
            assert done and done[0].value == 2
        finally:
            pool.shutdown()


class TestElasticScaler:
    def _run_burst(self, rec=None):
        log = EventLog()
        spec = PoolSpec("burst", size=1, min_size=1, max_size=4)
        pool = spec.build(event_log=log)
        scaler = ElasticScaler(
            {"burst": pool}, {"burst": spec},
            policy=ElasticPolicy(interval=0.01, step=2, idle_grace_ticks=2),
            event_log=log, rec=rec,
        )
        done = []
        scaler.start()
        try:
            for i in range(12):
                pool.submit(_mk_result(i, pool="burst"), lambda x: time.sleep(0.04) or x, done.append)
            deadline = time.monotonic() + 10.0
            while len(done) < 12 and time.monotonic() < deadline:
                time.sleep(0.01)
            time.sleep(0.3)  # idle: shrink back to the floor
        finally:
            scaler.stop()
            pool.shutdown()
        return log, scaler, pool, done

    def test_grow_shrink_within_bounds(self):
        log, scaler, pool, done = self._run_burst()
        assert len(done) == 12
        sizes = [new for _, _, _, new in scaler.resizes]
        assert sizes, "scaler never resized"
        assert max(sizes) <= 4 and min(sizes) >= 1
        assert any(new > old for _, _, old, new in scaler.resizes)   # grew
        assert pool.n_workers == 1                                   # shrank back

    def test_pool_resize_events_and_gauges(self):
        log, scaler, pool, done = self._run_burst()
        resizes = [e for e in log.events() if e.kind == "pool_resize"]
        gauges = [e for e in log.events() if e.kind == "gauge" and e.stage == "workers"]
        assert len(resizes) == len(scaler.resizes)
        assert {e.stage for e in resizes} == {"grow", "shrink"}
        assert all(e.info["old"] != e.info["new"] for e in resizes)
        # baseline gauge + one per resize
        assert len(gauges) == len(resizes) + 1
        agg = MetricsAggregator()
        for ev in log.events():
            agg.observe(ev)
        assert len(agg.pool_resizes) == len(resizes)
        assert (agg.fleet_worker_seconds("burst") or 0.0) > 0.0
        assert "pool_resize" not in agg.unknown_kinds

    def test_resource_counter_synced(self):
        rec = ResourceCounter(1, pools=["burst"])
        log, scaler, pool, done = self._run_burst(rec=rec)
        # fleet returned to the floor; so did the steering slots
        assert rec.allocation("burst") == pool.n_workers == 1

    def test_utilization_total_skips_uncovered_busy_pools(self):
        """Busy time from a pool with no known capacity must not inflate
        the total past 100% (numerator and denominator cover the same
        pools)."""
        from repro.observe import Event

        agg = MetricsAggregator()
        t0 = 100.0
        agg.observe(Event(t=t0, kind="gauge", stage="slots", pool="a", value=2.0))
        for pool, tid in (("a", "t1"), ("b", "t2")):
            agg.observe(Event(t=t0, kind="task", stage="submitted", task_id=tid,
                              method="m", pool=pool))
            agg.observe(Event(t=t0 + 0.5, kind="task", stage="running", task_id=tid,
                              method="m", pool=pool))
            agg.observe(Event(t=t0 + 1.5, kind="task", stage="completed", task_id=tid,
                              method="m", pool=pool))
        agg.observe(Event(t=t0 + 2.0, kind="gauge", stage="slots", pool="a", value=2.0))
        util = agg.utilization()
        assert util["a"] == pytest.approx(0.25)    # 1s busy / (2 slots * 2s)
        assert "b" not in util                     # no capacity known
        assert util["total"] == pytest.approx(0.25)  # pool b's busy time excluded
        # a declared-but-idle pool stays in the denominator: idle
        # capacity is exactly the waste the report exists to expose
        util2 = agg.utilization(slots_by_pool={"idle": 2})
        assert util2["idle"] == 0.0
        assert util2["total"] == pytest.approx(1.0 / (4.0 + 2.0 * 2.0))

    def test_pools_without_specs_rejected(self):
        pool = WorkerPool("p", 1, warm_capacity=0)
        try:
            with pytest.raises(ValueError, match="without specs"):
                ElasticScaler({"p": pool}, {})
        finally:
            pool.shutdown()

    def test_failed_rec_shrink_is_debt_not_desync(self):
        """A fleet shrink while steering slots are busy must not leave
        the ResourceCounter permanently above the fleet: the owed slots
        are reclaimed as they fall idle."""
        rec = ResourceCounter(4, pools=["p"])
        pool = WorkerPool("p", 4, warm_capacity=0)
        spec = PoolSpec("p", 4, min_size=1, max_size=4)
        scaler = ElasticScaler({"p": pool}, {"p": spec}, rec=rec)
        try:
            assert rec.acquire("p", 4, timeout=1)       # every slot busy
            scaler._sync_rec("p", 4, 2)                 # fleet shrank by 2
            assert rec.allocation("p") == 4             # nothing idle yet
            assert scaler._rec_debt["p"] == 2
            rec.release("p", 1)
            scaler._settle_rec_debt()                   # one slot reclaimable
            assert rec.allocation("p") == 3 and scaler._rec_debt["p"] == 1
            rec.release("p", 3)
            scaler._settle_rec_debt()
            assert rec.allocation("p") == 2 and scaler._rec_debt["p"] == 0
            # a later grow pays down debt before adding fresh capacity
            assert rec.acquire("p", 2, timeout=1)
            scaler._sync_rec("p", 2, 1)                 # shrink: all busy -> debt
            assert scaler._rec_debt["p"] == 1
            scaler._sync_rec("p", 1, 2)                 # grow: cancels the debt
            assert scaler._rec_debt["p"] == 0
            assert rec.allocation("p") == 2
        finally:
            pool.shutdown()


class TestAppElastic:
    def test_app_level_elastic_pool(self):
        from repro.app import AppSpec, ColmenaApp, ObserveSpec, PoolSpec as PS

        app = ColmenaApp(AppSpec(
            tasks={"work": lambda x: time.sleep(0.03) or x},
            pools={"default": PS("default", 1, min_size=1, max_size=4)},
            observe=ObserveSpec(elastic={"interval": 0.01, "step": 2, "idle_grace_ticks": 2}),
        ))
        with app.run() as handle:
            for i in range(12):
                handle.queues.send_inputs(i, method="work")
            vals = sorted(handle.queues.get_result(timeout=30).value for _ in range(12))
        assert vals == list(range(12))
        assert app.elastic is not None and app.elastic.resizes
        resizes = [e for e in app.event_log.events() if e.kind == "pool_resize"]
        assert resizes
        # utilization must use the resize-aware workers integral, never
        # the initial static size (which would report >100% once grown)
        util = app.observe_report()["utilization"]
        assert 0.0 < util["default"] <= 1.0

    def test_rebind_event_log_rebaselines_fleet_gauge(self):
        """A rebound log must get a fresh workers baseline so the fleet
        capacity integral has a left edge before the next resize."""
        from repro.app import AppSpec, ColmenaApp, ObserveSpec, PoolSpec as PS
        from repro.observe import EventLog

        app = ColmenaApp(AppSpec(
            tasks={"work": lambda x: x},
            pools={"default": PS("default", 2, min_size=1, max_size=4)},
            observe=ObserveSpec(elastic=True),
        ))
        app.build()
        try:
            fresh = EventLog()
            app.rebind_event_log(fresh)
            gauges = [e for e in fresh.events()
                      if e.kind == "gauge" and e.stage == "workers"]
            assert gauges and gauges[-1].value == 2.0
        finally:
            app._started = True  # allow stop() to tear down the built stack
            app.stop()

    def test_elastic_needs_a_band(self):
        from repro.app import AppSpec, ColmenaApp, ObserveSpec

        app = ColmenaApp(AppSpec(
            tasks={"work": lambda x: x},
            observe=ObserveSpec(elastic=True),
        ))
        with pytest.raises(ValueError, match="band"):
            app.build()

    def test_elastic_false_means_off(self):
        from repro.app import AppSpec, ColmenaApp, ObserveSpec

        app = ColmenaApp(AppSpec(
            tasks={"work": lambda x: x},
            observe=ObserveSpec(elastic=False),
        ))
        app.build()   # no "widen the band" error, no scaler composed
        try:
            assert app.elastic is None
        finally:
            app._started = True
            app.stop()

    def test_elastic_across_processes_builds_remote_pools(self):
        """Cross-process elasticity: elastic + an out-of-process server
        used to be rejected; now it composes ``RemotePool`` proxies that
        drive the spawned site's pools over the control channel."""
        from repro.app import (
            AppSpec, ColmenaApp, ObserveSpec, PoolSpec, QueueSpec, ServerSpec,
            TaskDef,
        )
        from repro.control import workload_task
        from repro.core.app import RemotePool

        app = ColmenaApp(AppSpec(
            tasks=[TaskDef(fn=workload_task, method="workload_task")],
            queues=QueueSpec(backend="pipe"),
            pools={"default": PoolSpec("default", 2, min_size=1, max_size=4)},
            server=ServerSpec(in_process=False),
            observe=ObserveSpec(elastic=True),
        ))
        with app.run(timeout=60):
            assert app.elastic is not None
            assert set(app.remote_pools) == {"default"}
            proxy = app.remote_pools["default"]
            assert isinstance(proxy, RemotePool)
            old, new = proxy.resize(3)
            assert (old, new) == (2, 3)
            assert proxy.n_workers == 3
