"""Property-based tests for the ProxyStore data fabric.

Round-trip invariants: arbitrary nested payloads pushed through the
auto-proxy threshold + the queue serializer come back identical whether
or not individual leaves crossed the threshold, and no LRU cache (store
cache or warm-worker cache) ever exceeds its configured capacity.
"""

import pickle
import uuid

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: pip install -e .[test]
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import (
    InMemoryConnector,
    Proxy,
    SharedMemoryConnector,
    Store,
    WarmCache,
    apply_threshold,
    resolve_all,
)
from repro.core.serialization import SERIALIZER, object_nbytes

SETTINGS = dict(
    max_examples=25, deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)

THRESHOLD = 800  # bytes — arrays of >= 100 float64s get proxied


def _fresh_store(**kwargs) -> Store:
    return Store(f"prop-{uuid.uuid4().hex[:12]}", InMemoryConnector(), **kwargs)


def _leaves():
    return st.one_of(
        st.integers(-1_000_000, 1_000_000),
        st.floats(allow_nan=False, allow_infinity=False),
        st.text(max_size=12),
        st.binary(max_size=32),
        st.none(),
        # both sides of the threshold: 8..64 B and 1600..4000 B
        st.integers(1, 8).map(lambda n: np.arange(n, dtype=np.float64)),
        st.integers(200, 500).map(lambda n: np.linspace(0.0, 1.0, n)),
    )


def _payloads():
    return st.recursive(
        _leaves(),
        lambda ch: st.one_of(
            st.lists(ch, max_size=4),
            st.dictionaries(st.text(max_size=4), ch, max_size=4),
            st.lists(ch, max_size=3).map(tuple),
        ),
        max_leaves=8,
    )


def _deep_equal(a, b) -> bool:
    if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
        return (
            isinstance(a, np.ndarray) and isinstance(b, np.ndarray)
            and a.shape == b.shape and a.dtype == b.dtype
            and np.array_equal(a, b)
        )
    if isinstance(a, (list, tuple)):
        return (
            type(a) is type(b) and len(a) == len(b)
            and all(_deep_equal(x, y) for x, y in zip(a, b))
        )
    if isinstance(a, dict):
        return (
            isinstance(b, dict) and a.keys() == b.keys()
            and all(_deep_equal(a[k], b[k]) for k in a)
        )
    return type(a) is type(b) and a == b


class TestRoundtripProperties:
    @given(_payloads())
    @settings(**SETTINGS)
    def test_threshold_serialize_roundtrip(self, payload):
        """proxy-above-threshold -> pickle -> unpickle -> resolve == id."""
        store = _fresh_store()
        converted, moved = apply_threshold(payload, store, THRESHOLD)
        blob, _ = SERIALIZER.serialize(converted)
        back, _ = SERIALIZER.deserialize(blob)
        assert _deep_equal(resolve_all(back), payload)
        assert moved >= 0
        # every proxied byte really was above the threshold
        if moved:
            assert moved >= THRESHOLD

    @given(_payloads())
    @settings(**SETTINGS)
    def test_threshold_moves_exactly_the_large_leaves(self, payload):
        store = _fresh_store()
        converted, moved = apply_threshold(payload, store, THRESHOLD)
        # apply_threshold walks one container level (Colmena semantics)
        top = (
            list(converted) if isinstance(converted, (list, tuple))
            else list(converted.values()) if isinstance(converted, dict)
            else [converted]
        )
        orig = (
            list(payload) if isinstance(payload, (list, tuple))
            else list(payload.values()) if isinstance(payload, dict)
            else [payload]
        )
        expect_moved = sum(
            object_nbytes(x) for x in orig
            if not isinstance(x, Proxy) and object_nbytes(x) >= THRESHOLD
        )
        assert moved == expect_moved
        for x, o in zip(top, orig):
            if isinstance(x, Proxy):
                assert object_nbytes(o) >= THRESHOLD

    @given(st.integers(200, 500))
    @settings(max_examples=10, deadline=None)
    def test_proxy_control_message_stays_small(self, n):
        store = _fresh_store()
        p = store.proxy(np.zeros(n))
        assert len(pickle.dumps(p)) < 1000


class TestLRUProperties:
    @given(
        st.integers(1, 8),
        st.lists(st.integers(0, 24), min_size=1, max_size=80),
    )
    @settings(**SETTINGS)
    def test_store_cache_never_exceeds_capacity(self, capacity, accesses):
        store = _fresh_store(cache_size=capacity)
        keys = {}
        for i in accesses:
            if i not in keys:
                keys[i] = store.put(np.full(4, float(i)))
            got = store.get(keys[i])
            assert got[0] == float(i)
            assert len(store._cache) <= capacity
        # eviction never corrupted the backing connector
        for i, k in keys.items():
            assert store.get(k, use_cache=False)[0] == float(i)

    @given(
        st.integers(1, 8),
        st.lists(st.tuples(st.integers(0, 24), st.integers(0, 99)),
                 min_size=1, max_size=80),
    )
    @settings(**SETTINGS)
    def test_warm_cache_never_exceeds_capacity(self, capacity, ops):
        warm = WarmCache(capacity)
        shadow = {}
        for key_i, value in ops:
            key = ("method", "store", str(key_i))
            got = warm.lookup(key)
            if got is not WarmCache._MISS:
                # a hit must return the last inserted value for the key
                assert got == shadow[key]
            else:
                warm.insert(key, value)
                shadow[key] = value
            assert len(warm) <= capacity
        assert warm.stats.hits + warm.stats.misses == len(ops)


class TestSharedMemoryConnector:
    @given(
        st.sampled_from([np.float32, np.float64, np.int32]),
        st.integers(1, 400),
    )
    @settings(max_examples=10, deadline=None)
    def test_array_roundtrip_zero_copy(self, dtype, n):
        conn = SharedMemoryConnector(prefix=f"t{uuid.uuid4().hex[:6]}")
        try:
            arr = np.arange(n, dtype=dtype)
            conn.put("k", arr)
            out = conn.get("k")
            assert isinstance(out, np.ndarray)
            assert out.dtype == arr.dtype and np.array_equal(out, arr)
            assert out.base is not None  # a view over the shm buffer, not a copy
        finally:
            conn.close()

    def test_pickle_fallback_and_evict(self):
        conn = SharedMemoryConnector(prefix=f"t{uuid.uuid4().hex[:6]}")
        try:
            conn.put("k", {"a": [1, 2], "b": "text"})
            assert conn.get("k") == {"a": [1, 2], "b": "text"}
            assert conn.exists("k")
            conn.evict("k")
            assert not conn.exists("k")
        finally:
            conn.close()

    def test_proxy_pickle_roundtrip_through_shm(self):
        conn = SharedMemoryConnector(prefix=f"t{uuid.uuid4().hex[:6]}")
        try:
            store = Store(f"shm-{uuid.uuid4().hex[:8]}", conn)
            arr = np.linspace(0, 1, 64)
            p = pickle.loads(pickle.dumps(store.proxy(arr)))
            assert np.allclose(np.asarray(p.resolve()), arr)
        finally:
            conn.close()
