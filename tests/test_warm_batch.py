"""Warm-worker cache + batched dispatch: correctness and fault injection.

Covers the warm-worker execution layer: per-worker sticky caches resolve
shared proxied payloads once per worker (hits/misses in the event log),
batched dispatch coalesces same-method tasks into one worker round-trip
with correct per-task timing, and a worker dying mid-batch (exception or
heartbeat loss) gets its whole batch retried cold on another worker with
no lost or duplicated Results.
"""

import pickle
import threading
import time
import uuid

import numpy as np

from repro.core import (
    BatchPolicy,
    InMemoryConnector,
    LocalColmenaQueues,
    RetryPolicy,
    Store,
    StragglerPolicy,
    TaskServer,
    WorkerDied,
    WorkerPool,
)
from repro.observe import EventLog, MetricsAggregator, lifecycle_gaps


def _clone(proxy):
    """Fresh Proxy instance, as a cross-process control message carries."""
    return pickle.loads(pickle.dumps(proxy))


def _fresh_store(**kwargs) -> Store:
    # cache_size=0 so only the warm-worker cache can short-circuit fetches
    return Store(f"wb-{uuid.uuid4().hex[:12]}", InMemoryConnector(), **kwargs)


class TestWarmCache:
    def test_one_miss_then_hits_per_worker(self):
        log = EventLog()
        store = _fresh_store(cache_size=0)
        queues = LocalColmenaQueues(proxystore=store, event_log=log)
        ref = store.proxy(np.ones(64))
        server = TaskServer(
            queues, {"f": lambda m, i: float(np.sum(m)) + i},
            pools={"default": WorkerPool("default", 1, warm_capacity=8)},
            event_log=log,
        ).start()
        for i in range(6):
            queues.send_inputs(_clone(ref), i, method="f")
        results = [queues.get_result(timeout=10) for _ in range(6)]
        server.stop()
        assert all(r is not None and r.success for r in results)
        assert sorted(r.value for r in results) == [64.0 + i for i in range(6)]

        cache = MetricsAggregator(log).cache_stats()
        assert cache["f"].misses == 1          # resolved once on the worker
        assert cache["f"].hits == 5            # served warm thereafter
        assert cache["total"].hit_rate > 0.8
        assert store.metrics.gets <= 2         # fabric touched once (+prefetch)

    def test_disabled_cache_emits_no_events(self):
        log = EventLog()
        store = _fresh_store(cache_size=0)
        queues = LocalColmenaQueues(proxystore=store, event_log=log)
        ref = store.proxy(np.ones(8))
        server = TaskServer(
            queues, {"f": lambda m: float(np.sum(m))},
            pools={"default": WorkerPool("default", 1, warm_capacity=0)},
            event_log=log,
        ).start()
        for _ in range(3):
            queues.send_inputs(_clone(ref), method="f")
        results = [queues.get_result(timeout=10) for _ in range(3)]
        server.stop()
        assert all(r.success for r in results)
        total = MetricsAggregator(log).cache_stats()["total"]
        assert total.hits == 0 and total.misses == 0


class TestBatchedDispatch:
    def test_batch_coalesces_with_correct_results(self):
        log = EventLog()
        queues = LocalColmenaQueues(event_log=log)
        # enqueue before the server starts so one full batch forms
        for i in range(12):
            queues.send_inputs(i, method="sq")
        server = TaskServer(
            queues, {"sq": lambda x: x * x}, n_workers=2,
            batching=BatchPolicy(max_batch=4, linger_s=0.05),
            event_log=log,
        ).start()
        results = [queues.get_result(timeout=10) for _ in range(12)]
        server.stop()
        assert all(r is not None and r.success for r in results)
        assert sorted(r.value for r in results) == sorted(i * i for i in range(12))
        assert len({r.task_id for r in results}) == 12  # split back 1:1

        batches = MetricsAggregator(log).batch_stats()["sq"]
        assert batches.tasks == 12
        assert batches.batches < 12            # real coalescing happened
        assert batches.max_occupancy >= 2
        assert not lifecycle_gaps(log)

    def test_per_task_timing_within_batch(self):
        queues = LocalColmenaQueues()
        for i in range(3):
            queues.send_inputs(i, method="nap")
        server = TaskServer(
            queues, {"nap": lambda i: time.sleep(0.02) or i},
            pools={"default": WorkerPool("default", 1)},
            batching=BatchPolicy(max_batch=3, linger_s=0.05),
        ).start()
        results = [queues.get_result(timeout=10) for _ in range(3)]
        server.stop()
        assert all(r.success for r in results)
        spans = sorted(
            (r.time.compute_started, r.time.compute_ended) for r in results
        )
        for start, end in spans:
            assert end - start >= 0.02          # each task carries its own span
        for (_, prev_end), (next_start, _) in zip(spans, spans[1:]):
            assert next_start >= prev_end       # batch members ran back-to-back

    def test_method_filter_limits_batching(self):
        log = EventLog()
        queues = LocalColmenaQueues(event_log=log)
        for i in range(4):
            queues.send_inputs(i, method="a")
            queues.send_inputs(i, method="b")
        server = TaskServer(
            queues, {"a": lambda x: x, "b": lambda x: -x}, n_workers=2,
            batching=BatchPolicy(max_batch=4, linger_s=0.05, methods=("a",)),
            event_log=log,
        ).start()
        results = [queues.get_result(timeout=10) for _ in range(8)]
        server.stop()
        assert all(r.success for r in results)
        stats = MetricsAggregator(log).batch_stats()
        assert stats.get("a") is not None and stats["a"].tasks == 4
        assert "b" not in stats                 # ineligible: never batched


class TestMidBatchWorkerDeath:
    def test_batch_retried_cold_no_lost_or_duplicated_results(self):
        log = EventLog()
        store = _fresh_store(cache_size=0)
        queues = LocalColmenaQueues(proxystore=store, event_log=log)
        ref = store.proxy(np.arange(8.0))
        bomb_armed = threading.Event()
        bomb_armed.set()

        def f(m, i):
            if i == 1 and bomb_armed.is_set():
                bomb_armed.clear()             # only the first attempt dies
                raise WorkerDied("injected mid-batch node loss")
            return float(m[0]) + i

        for i in range(4):                      # full batch forms pre-start
            queues.send_inputs(_clone(ref), i, method="f")
        server = TaskServer(
            queues, {"f": f},
            pools={"default": WorkerPool("default", 2, warm_capacity=8)},
            batching=BatchPolicy(max_batch=4, linger_s=0.05),
            retry=RetryPolicy(max_retries=2),
            event_log=log,
        ).start()
        results = [queues.get_result(timeout=15) for _ in range(4)]
        # no lost results ...
        assert all(r is not None and r.success for r in results)
        assert sorted(r.value for r in results) == [0.0, 1.0, 2.0, 3.0]
        # ... and no duplicated ones
        assert queues.get_result(timeout=0.3) is None
        assert len({r.task_id for r in results}) == 4

        # tasks 1 (the bomb), 2, 3 (mid-batch victims) were retried ...
        assert server.metrics.tasks_retried == 3
        # ... on a different worker than the one that died
        dead_wid = next(
            r.worker_id for r in results
            if r.value == 0.0                   # task 0 completed pre-death
        )
        retried_events = [e for e in log.events() if e.stage == "retried"]
        assert len(retried_events) == 3
        retried_values = {1.0, 2.0, 3.0}
        assert all(
            r.worker_id != dead_wid for r in results if r.value in retried_values
        )
        # retries resolved the payload cold (fresh cache miss elsewhere):
        # one miss on the dead worker, one on the retry worker
        cache = MetricsAggregator(log).cache_stats()["f"]
        assert cache.misses >= 2
        assert not lifecycle_gaps(log)
        server.stop()

    def test_heartbeat_failover_drops_zombie_duplicates(self):
        log = EventLog()
        queues = LocalColmenaQueues(event_log=log)
        pool = WorkerPool("default", 2)
        for i in range(3):
            queues.send_inputs(i, method="slow")
        server = TaskServer(
            queues, {"slow": lambda i: time.sleep(0.4) or i},
            pools={"default": pool},
            batching=BatchPolicy(max_batch=3, linger_s=0.05),
            straggler=StragglerPolicy(enabled=False, check_interval_s=0.05),
            heartbeat_timeout_s=0.2,
        ).start()
        deadline = time.time() + 5
        while time.time() < deadline:           # wait for the batch to start
            busy = [w for w in pool.worker_states() if w.busy]
            if busy:
                break
            time.sleep(0.01)
        assert busy
        # node loss while holding a 3-task batch: the thread keeps running
        # (a zombie), but all 3 tasks must fail over and be retried
        pool.kill_worker(busy[0].worker_id)
        results = [queues.get_result(timeout=15) for _ in range(3)]
        assert all(r is not None and r.success for r in results)
        assert sorted(r.value for r in results) == [0, 1, 2]
        # the zombie's late completions were dropped, not double-sent
        assert queues.get_result(timeout=0.6) is None
        server.stop()
