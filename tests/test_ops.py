"""Tests for the live ops plane: the streaming SLO engine's multi-window
burn-rate state machine (fake clock), alert-driven auto-remediation, the
EWMA/z-score anomaly detector, the stdlib HTTP ops server (endpoints +
lifecycle), app-level wiring, spec-file round-trips of the new observe
knobs, and the budget-aware retrain cadence."""

import json
import urllib.request

import pytest

from repro.observe import (
    AnomalyDetector,
    AnomalySpec,
    EventLog,
    MetricsAggregator,
    OpsServer,
    SLOEngine,
    SLOObjective,
    SLOSpec,
)
from repro.observe.slo import _BurnWindow, default_objectives


def _get(url, timeout=10):
    with urllib.request.urlopen(url, timeout=timeout) as resp:
        return resp.status, resp.read().decode("utf-8")


def _get_code(url, timeout=10):
    try:
        return _get(url, timeout=timeout)[0]
    except urllib.error.HTTPError as err:
        return err.code


class _FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


class TestBurnWindow:
    def test_burn_is_bad_fraction_over_budget(self):
        w = _BurnWindow(horizon_s=10.0)
        for t, bad in ((0.0, True), (1.0, False), (2.0, True), (3.0, True)):
            w.add(t, bad)
        assert w.burn(now=3.0, budget=0.5, min_samples=1) == pytest.approx(1.5)

    def test_eviction_and_min_samples(self):
        w = _BurnWindow(horizon_s=1.0)
        w.add(0.0, True)
        w.add(0.5, True)
        assert w.burn(now=0.5, budget=1.0, min_samples=3) is None  # too thin
        assert w.burn(now=5.0, budget=1.0, min_samples=1) is None  # all evicted


class TestSLOStateMachine:
    """Drive the engine tick-by-tick on a fake clock via a gauge signal."""

    def _engine(self, clock, **obj_kwargs):
        log = EventLog()
        obj = SLOObjective(
            name="qdepth", signal="gauge", gauge="qdepth", threshold=10.0,
            kind="ceiling", budget=0.4, fast_window_s=1.0, slow_window_s=10.0,
            min_samples=2, **obj_kwargs,
        )
        eng = SLOEngine(log, SLOSpec(objectives=[obj], interval_s=0.05),
                        clock=clock)
        return log, eng

    def _feed(self, log, eng, t, value):
        log.gauge("qdepth", value)
        eng.tick(now=t)

    def test_pending_firing_resolved_lifecycle(self):
        clock = _FakeClock()
        log, eng = self._engine(clock)
        # Seed the slow window with good samples so the fast window can
        # burn hot while the slow one stays diluted (pending, not firing).
        for i in range(6):
            self._feed(log, eng, float(i), 1.0)
        for t in (9.5, 9.6, 9.7):
            self._feed(log, eng, t, 100.0)
        assert [tr["to"] for tr in eng.transitions] == ["pending"]
        assert eng.firing() == []
        # More bad samples push the slow window hot too: firing.
        for t in (10.0, 10.5, 11.0):
            self._feed(log, eng, t, 100.0)
        assert eng.firing() == ["qdepth"]
        # Good samples drain the fast window below resolve_burn: resolved.
        for t in (12.0, 12.2, 12.4):
            self._feed(log, eng, t, 1.0)
        assert eng.firing() == []
        edges = [(tr["from"], tr["to"]) for tr in eng.transitions]
        assert edges == [("ok", "pending"), ("pending", "firing"), ("firing", "ok")]
        fired, resolve = eng.transitions[1], eng.transitions[-1]
        assert resolve["firing_s"] == pytest.approx(resolve["t"] - fired["t"])
        stages = [ev.stage for ev in log.events() if ev.kind == "alert"]
        assert stages == ["pending", "firing", "resolved"]

    def test_transient_blip_never_pages(self):
        clock = _FakeClock()
        log, eng = self._engine(clock)
        for i in range(8):
            self._feed(log, eng, float(i), 1.0)
        for t in (9.5, 9.6):  # brief spike: fast hot, slow still cool
            self._feed(log, eng, t, 100.0)
        assert [tr["to"] for tr in eng.transitions] == ["pending"]
        for t in (11.0, 11.2, 11.4):  # recovery before the slow window heats
            self._feed(log, eng, t, 1.0)
        edges = [(tr["from"], tr["to"]) for tr in eng.transitions]
        assert edges == [("ok", "pending"), ("pending", "ok")]
        # The de-escalation is silent: no resolved alert for a pending blip.
        stages = [ev.stage for ev in log.events() if ev.kind == "alert"]
        assert stages == ["pending"]

    def test_floor_objective_fires_on_low_values(self):
        clock = _FakeClock()
        log = EventLog()
        obj = SLOObjective(
            name="util-floor", signal="gauge", gauge="util", threshold=0.5,
            kind="floor", budget=0.4, fast_window_s=1.0, slow_window_s=10.0,
            min_samples=2,
        )
        eng = SLOEngine(log, SLOSpec(objectives=[obj]), clock=clock)
        for t in (0.0, 0.2, 0.4, 0.6):
            log.gauge("util", 0.1)
            eng.tick(now=t)
        assert eng.firing() == ["util-floor"]

    def test_min_samples_gates_thin_windows(self):
        clock = _FakeClock()
        log, eng = self._engine(clock)
        self._feed(log, eng, 0.0, 100.0)  # one bad sample < min_samples=2
        assert eng.transitions == []

    def test_alerts_accessor_shape(self):
        clock = _FakeClock()
        log, eng = self._engine(clock)
        (alert,) = eng.alerts()
        assert alert["name"] == "qdepth" and alert["state"] == "ok"
        assert alert["signal"] == "gauge" and alert["threshold"] == 10.0

    def test_objective_validation(self):
        with pytest.raises(ValueError):
            SLOObjective(name="x", signal="nope")
        with pytest.raises(ValueError):
            SLOObjective(name="x", signal="gauge")  # needs gauge name
        with pytest.raises(ValueError):
            SLOObjective(name="x", signal="latency", fast_window_s=10.0,
                         slow_window_s=5.0)  # fast must be shorter
        with pytest.raises(ValueError):
            SLOObjective(name="x", signal="loss_rate", threshold=2.0)

    def test_spec_from_any_shapes(self):
        assert len(SLOSpec.from_any(True).objectives) == len(default_objectives())
        spec = SLOSpec.from_any([{"name": "lat", "signal": "latency", "threshold": 2.0}])
        assert spec.objectives[0].name == "lat"
        spec = SLOSpec.from_any({"interval_s": 0.5, "objectives": [
            {"name": "bl", "signal": "backlog", "threshold": 50.0}]})
        assert spec.interval_s == 0.5 and spec.objectives[0].signal == "backlog"
        with pytest.raises(ValueError):
            SLOSpec.from_any({"bogus": 1})


class TestRemediation:
    def _firing_engine(self, handlers):
        clock = _FakeClock()
        log = EventLog()
        obj = SLOObjective(
            name="qdepth", signal="gauge", gauge="qdepth", threshold=10.0,
            budget=0.4, fast_window_s=1.0, slow_window_s=10.0, min_samples=2,
        )
        eng = SLOEngine(log, SLOSpec(objectives=[obj]), clock=clock)
        for selector, fn, label in handlers:
            eng.on_fire(selector, fn, label=label)
        for t in (0.0, 0.2, 0.4):
            log.gauge("qdepth", 100.0)
            eng.tick(now=t)
        assert eng.firing() == ["qdepth"]
        return log, eng

    def test_handler_runs_once_per_firing_and_is_recorded(self):
        calls = []
        log, eng = self._firing_engine(
            [("qdepth", lambda alert: calls.append(alert) or {"grown": 2}, "grow")])
        assert len(calls) == 1 and calls[0]["name"] == "qdepth"
        assert eng.remediations_run == 1
        evs = [ev for ev in log.events() if ev.kind == "remediation"]
        assert len(evs) == 1
        assert evs[0].stage == "grow" and evs[0].info["ok"] is True
        assert evs[0].info["alert"] == "qdepth"
        # Still firing on later ticks: no re-run without a new transition.
        eng.tick(now=0.6)
        assert eng.remediations_run == 1

    def test_selector_matching(self):
        hits = []
        self._firing_engine([
            ("qdepth", lambda a: hits.append("name"), "by-name"),
            ("gauge", lambda a: hits.append("signal"), "by-signal"),
            ("*", lambda a: hits.append("star"), "by-star"),
            ("other", lambda a: hits.append("other"), "no-match"),
        ])
        assert sorted(hits) == ["name", "signal", "star"]

    def test_failing_handler_recorded_not_fatal(self):
        def boom(alert):
            raise RuntimeError("remediation exploded")

        log, eng = self._firing_engine([("*", boom, "boom")])
        assert eng.remediations_run == 1
        (ev,) = [ev for ev in log.events() if ev.kind == "remediation"]
        assert ev.info["ok"] is False
        assert "RuntimeError" in ev.info["detail"]


class TestAnomalyDetector:
    def test_spike_fires_advisory_and_resolves(self):
        clock = _FakeClock()
        log = EventLog()
        det = AnomalyDetector(
            log, AnomalySpec(alpha=0.2, z_threshold=4.0, resolve_z=2.0,
                             min_samples=10, series=("arrival_rate",)),
            clock=clock)
        for i in range(20):  # learn a noisy-flat baseline
            log.gauge("arrival_rate", 10.0 + (i % 3) * 0.1, pool="p")
            det.tick(now=float(i))
        assert det.firing() == []
        log.gauge("arrival_rate", 50.0, pool="p")  # 20x the learned spread
        det.tick(now=21.0)
        assert det.firing() == ["anomaly:arrival_rate"]
        (alert,) = [a for a in det.alerts() if a["state"] == "firing"]
        assert alert["severity"] == "advisory"
        # EWMA absorbs the new level; hysteresis resolves the alert.
        for i in range(30):
            log.gauge("arrival_rate", 50.0, pool="p")
            det.tick(now=22.0 + i)
        assert det.firing() == []
        stages = [ev.stage for ev in log.events() if ev.kind == "alert"]
        assert stages == ["firing", "resolved"]

    def test_warmup_never_alerts(self):
        clock = _FakeClock()
        log = EventLog()
        det = AnomalyDetector(log, AnomalySpec(min_samples=50,
                                               series=("arrival_rate",)),
                              clock=clock)
        for i in range(30):
            log.gauge("arrival_rate", 1.0 if i % 2 else 1000.0, pool="p")
            det.tick(now=float(i))
        assert det.firing() == [] and det.alerts()[0]["state"] == "ok"

    def test_spec_validation(self):
        with pytest.raises(ValueError):
            AnomalySpec(alpha=0.0)
        with pytest.raises(ValueError):
            AnomalySpec(resolve_z=5.0, z_threshold=4.0)
        with pytest.raises(ValueError):
            AnomalySpec(series=("nope",))


class TestOpsServer:
    def _server(self, **kwargs):
        srv = OpsServer(**kwargs).start()
        return srv

    def test_endpoint_index_and_404(self):
        srv = self._server()
        try:
            code, body = _get(srv.url + "/")
            doc = json.loads(body)
            assert code == 200 and "/metrics" in doc["endpoints"]
            assert _get_code(srv.url + "/bogus") == 404
        finally:
            srv.stop()

    def test_lifecycle_states_drive_health_codes(self):
        srv = self._server()
        try:
            assert srv.state == "starting"
            assert _get_code(srv.url + "/healthz") == 200
            assert _get_code(srv.url + "/readyz") == 503
            srv.set_state("ready")
            assert _get_code(srv.url + "/readyz") == 200
            srv.set_state("draining")
            assert _get_code(srv.url + "/healthz") == 200
            assert _get_code(srv.url + "/readyz") == 503
            srv.set_state("stopped")
            assert _get_code(srv.url + "/healthz") == 503
            with pytest.raises(ValueError):
                srv.set_state("bogus")
        finally:
            srv.stop()

    def test_metrics_and_snapshot_need_aggregator(self):
        srv = self._server()  # no aggregator bound
        try:
            assert _get_code(srv.url + "/metrics") == 503
            assert _get_code(srv.url + "/snapshot") == 503
        finally:
            srv.stop()

    def test_alerts_endpoint_merges_slo_and_anomaly(self):
        clock = _FakeClock()
        log = EventLog()
        obj = SLOObjective(name="qdepth", signal="gauge", gauge="qdepth",
                           threshold=10.0, budget=0.4, fast_window_s=1.0,
                           slow_window_s=10.0, min_samples=2)
        eng = SLOEngine(log, SLOSpec(objectives=[obj]), clock=clock)
        det = AnomalyDetector(log, AnomalySpec(series=("arrival_rate",)),
                              clock=clock)
        for t in (0.0, 0.2, 0.4):
            log.gauge("qdepth", 100.0)
            eng.tick(now=t)
        srv = self._server(slo=eng, anomaly=det)
        try:
            code, body = _get(srv.url + "/alerts")
            doc = json.loads(body)
            assert code == 200 and doc["firing"] == ["qdepth"]
            names = {a["name"] for a in doc["alerts"]}
            assert {"qdepth", "anomaly:arrival_rate"} <= names
        finally:
            srv.stop()


class TestMetricsParity:
    def test_http_metrics_match_prom_file(self, tmp_path):
        """``GET /metrics`` and the exporter's ``metrics.prom`` render the
        same aggregator: byte-identical once the log quiesces."""
        from repro.core import (
            LocalColmenaQueues, ResourceRequest, TaskServer, WorkerPool,
        )
        from repro.observe import ExportSpec, MetricsExporter

        log = EventLog()
        q = LocalColmenaQueues(event_log=log)
        server = TaskServer(
            q, {"work": lambda x: x * 2},
            pools={"alpha": WorkerPool("alpha", 2), "default": WorkerPool("default", 1)},
        ).start()
        for i in range(6):
            q.send_inputs(i, method="work", resources=ResourceRequest(pool="alpha"))
        assert all(q.get_result(timeout=30).success for _ in range(6))
        server.stop()

        slots = {"alpha": 2}
        agg = MetricsAggregator(log)
        exporter = MetricsExporter(
            log, spec=ExportSpec(dir=str(tmp_path)), slots_by_pool=slots,
            aggregator=agg)
        exporter.write_once()
        srv = OpsServer(aggregator=agg, slots_by_pool=slots).start()
        try:
            code, body = _get(srv.url + "/metrics")
        finally:
            srv.stop()
        assert code == 200
        assert body == (tmp_path / "metrics.prom").read_text()
        assert "repro_pool_completed" in body


class TestAppOpsIntegration:
    def test_ops_plane_serves_live_campaign(self, tmp_path):
        from repro.app import AppSpec, ColmenaApp, ObserveSpec

        app = ColmenaApp(AppSpec(
            tasks={"double": lambda x: x * 2},
            pools={"default": 2},
            observe=ObserveSpec(
                ops_port=0,
                slo=[{"name": "backlog", "signal": "backlog",
                      "threshold": 1e6, "budget": 0.5}],
                anomaly={"min_samples": 5},
                remediate=False,
            ),
        ))
        with app.run(timeout=60) as handle:
            assert app.ops is not None and app.ops.state == "ready"
            url = app.ops.url
            assert _get_code(url + "/readyz") == 200
            for i in range(5):
                handle.queues.send_inputs(i, method="double")
            assert all(handle.queues.get_result(timeout=30).success
                       for _ in range(5))
            # Live scrape mid-campaign matches the shared aggregator.
            code, body = _get(url + "/metrics")
            assert code == 200
            assert body == app.aggregator.prometheus_text(
                slots_by_pool={"default": 2})
            code, body = _get(url + "/snapshot")
            assert json.loads(body)["methods"]["double"]["count"] == 5
            code, body = _get(url + "/alerts")
            doc = json.loads(body)
            assert doc["firing"] == []
            assert {a["name"] for a in doc["alerts"]} >= {
                "backlog", "anomaly:latency"}
        assert app.ops.state == "stopped"

    def test_remediate_requires_slo(self):
        from repro.app import AppSpec, ObserveSpec

        with pytest.raises(ValueError, match="remediate"):
            AppSpec(tasks={"f": lambda x: x},
                    observe=ObserveSpec(remediate=True))


class TestSpecfileOpsKnobs:
    def test_roundtrip_ops_slo_anomaly_knobs(self):
        from repro.app import AppSpec, ObserveSpec
        from repro.core.specfile import spec_from_dict, spec_to_dict

        spec = AppSpec(
            tasks={"double": _spec_double},
            observe=ObserveSpec(
                ops_port=9137,
                slo={"interval_s": 0.5, "objectives": [
                    {"name": "lat", "signal": "latency", "threshold": 2.0}]},
                anomaly={"z_threshold": 5.0},
                remediate=True,
            ),
        )
        d = spec_to_dict(spec)
        assert d["observe"]["ops_port"] == 9137
        assert d["observe"]["remediate"] is True
        assert d["observe"]["slo"]["objectives"][0]["name"] == "lat"
        back = spec_from_dict(d)
        assert back.observe.ops_port == 9137 and back.observe.remediate
        assert back.observe.slo["interval_s"] == 0.5
        assert back.observe.anomaly["z_threshold"] == 5.0

    def test_roundtrip_bare_true_knobs(self):
        from repro.app import AppSpec, ObserveSpec
        from repro.core.specfile import spec_from_dict, spec_to_dict

        spec = AppSpec(tasks={"double": _spec_double},
                       observe=ObserveSpec(slo=True, anomaly=True))
        d = spec_to_dict(spec)
        assert d["observe"]["slo"] == {} and d["observe"]["anomaly"] == {}
        back = spec_from_dict(d)
        # A bare table means "defaults": both engines enabled.
        assert back.observe.slo is not None
        assert back.observe.anomaly is not None


def _spec_double(x):
    return x * 2


class TestAdaptiveRetrainCadence:
    def test_cadence_scales_with_throughput_and_budget(self):
        from repro.surrogate.thinker import adaptive_retrain_after

        # 0.5 s per retrain at 100 tasks/s with a 20% training budget:
        # one retrain every 0.5*100*(0.8/0.2) = 200 results.
        assert adaptive_retrain_after(16, 0.5, 100.0, 0.2) == 200
        # A looser budget retrains more often; a tighter one less.
        assert adaptive_retrain_after(16, 0.5, 100.0, 0.5) == 50
        assert adaptive_retrain_after(16, 0.5, 100.0, 0.1) == 450

    def test_clamps_and_invalid_inputs(self):
        from repro.surrogate.thinker import adaptive_retrain_after

        assert adaptive_retrain_after(16, 100.0, 1000.0, 0.01, hi=4096) == 4096
        assert adaptive_retrain_after(16, 1e-6, 1.0, 0.9, lo=4) == 4
        # Invalid readings keep the current cadence.
        assert adaptive_retrain_after(16, 0.0, 100.0, 0.2) == 16
        assert adaptive_retrain_after(16, 0.5, 0.0, 0.2) == 16
        assert adaptive_retrain_after(16, 0.5, 100.0, 0.0) == 16

    def test_thinker_rejects_bad_budget(self):
        import numpy as np

        from repro.core import LocalColmenaQueues
        from repro.surrogate import DeepEnsemble, make_policy
        from repro.surrogate.thinker import ActiveLearningThinker

        with pytest.raises(ValueError, match="retrain_budget"):
            ActiveLearningThinker(
                LocalColmenaQueues(topics=["simulate", "train"]),
                ensemble=DeepEnsemble(2), policy=make_policy("ucb"),
                candidates=np.zeros((8, 2), np.float32), n_slots=2,
                retrain_after=4, retrain_budget=1.5,
            )

    def test_adapt_cadence_mutates_live_and_gauges(self):
        import numpy as np

        from repro.core import LocalColmenaQueues
        from repro.surrogate import DeepEnsemble, make_policy
        from repro.surrogate.thinker import ActiveLearningThinker

        log = EventLog()
        thinker = ActiveLearningThinker(
            LocalColmenaQueues(topics=["simulate", "train"]),
            ensemble=DeepEnsemble(2), policy=make_policy("ucb"),
            candidates=np.zeros((8, 2), np.float32), n_slots=2,
            retrain_after=4, retrain_budget=0.2,
        )
        import time as _time

        thinker._first_result_t = _time.monotonic() - 10.0  # 10 s of results
        thinker._train_seconds = 2.0
        thinker._adapt_cadence(duration_s=2.0, n_results=100, log=log)
        # throughput ~10/s, 2 s per retrain, 20% budget -> cadence ~80.
        assert 70 <= thinker.retrain_after <= 90
        gauges = {ev.stage for ev in log.events() if ev.kind == "gauge"}
        assert {"retrain_budget", "retrain_after"} <= gauges
