"""Control plane: the durable campaign state machine, fair-share
scheduler, in-process plane lifecycle (concurrent campaigns, preemption
checkpoint/restore), the HTTP API, the remote-site resize channel, and
the daemon SIGKILL -> auto-resume path."""

import json
import os
import signal
import subprocess
import sys
import time
import urllib.error
import urllib.request

import pytest

from repro.control import (
    DONE,
    FAILED,
    PAUSED,
    RUNNING,
    STAGED,
    SUBMITTED,
    CampaignRecord,
    ControlPlane,
    ControlServer,
    IllegalTransition,
    StateStore,
    compute_grants,
    meets_floor,
    total_slots,
)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _campaign_toml(n_tasks=24, n_parallel=4, task_s=0.0, pool_size=4,
                   weight=1.0, priority=0, min_slots=1, checkpoint_s=0.5):
    return f"""
[[tasks]]
fn = "repro.control.workload.workload_task"

[pools.default]
size = {pool_size}

[steering]
thinker = "repro.control.workload.make_workload"

[steering.kwargs]
n_tasks = {n_tasks}
n_parallel = {n_parallel}
task_s = {task_s}

[campaign]
checkpoint_interval_s = {checkpoint_s}

[control]
weight = {weight}
priority = {priority}
min_slots = {min_slots}
"""


def _journal_indices(store, cid):
    path = os.path.join(store.state_dir(cid), "results.jsonl")
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line)["index"])
            except (ValueError, KeyError):
                continue  # torn tail line from a SIGKILL mid-append
    return out


def _wait(predicate, timeout=30.0, interval=0.1, msg="condition"):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if predicate():
            return
        time.sleep(interval)
    raise AssertionError(f"timed out waiting for {msg}")


class TestStateMachine:
    def test_illegal_transitions_rejected(self, tmp_path):
        store = StateStore(str(tmp_path))
        rec = store.create("c", "x = 1")
        with pytest.raises(IllegalTransition):
            store.transition(rec.id, RUNNING)       # submitted -/-> running
        with pytest.raises(IllegalTransition):
            store.transition(rec.id, DONE)          # submitted -/-> done
        with pytest.raises(IllegalTransition):
            store.transition(rec.id, "nonsense")
        store.transition(rec.id, STAGED)
        store.transition(rec.id, RUNNING)
        store.transition(rec.id, DONE)
        for s in (STAGED, RUNNING, PAUSED, FAILED):
            with pytest.raises(IllegalTransition):  # done is terminal
                store.transition(rec.id, s)
        # the rejected edges never touched the durable record
        assert StateStore(str(tmp_path)).get(rec.id).state == DONE

    def test_records_survive_restart(self, tmp_path):
        store = StateStore(str(tmp_path))
        rec = store.create("persist-me", "[pools.default]\nsize = 2\n",
                           weight=2.5, priority=1, min_slots=2, demand={"default": 2})
        store.transition(rec.id, STAGED, reason="admitted")
        again = StateStore(str(tmp_path))
        got = again.get(rec.id)
        assert (got.name, got.state, got.weight, got.priority, got.min_slots) == \
            ("persist-me", STAGED, 2.5, 1, 2)
        assert got.demand == {"default": 2}
        assert [h[0] for h in got.history] == [SUBMITTED, STAGED]
        with open(again.spec_path(rec.id)) as f:
            assert f.read() == "[pools.default]\nsize = 2\n"

    def test_recover_restages_every_non_terminal(self, tmp_path):
        store = StateStore(str(tmp_path))
        recs = {}
        for state in (SUBMITTED, STAGED, RUNNING, PAUSED, DONE):
            r = store.create(state, "x = 1")
            recs[state] = r.id
            for step in {SUBMITTED: [], STAGED: [STAGED],
                         RUNNING: [STAGED, RUNNING],
                         PAUSED: [STAGED, RUNNING, PAUSED],
                         DONE: [STAGED, RUNNING, DONE]}[state]:
                store.transition(r.id, step)
        user = store.create("user-paused", "x = 1")
        for step in (STAGED, RUNNING, PAUSED):
            store.transition(user.id, step)
        store.set_paused_by_user(user.id, True)

        fresh = StateStore(str(tmp_path))  # the post-SIGKILL reload
        restaged = {r.name for r in fresh.recover()}
        assert restaged == {SUBMITTED, STAGED, RUNNING, PAUSED}
        assert fresh.get(recs[RUNNING]).state == STAGED
        assert fresh.get(recs[RUNNING]).resumed >= 1
        assert fresh.get(recs[DONE]).state == DONE
        assert fresh.get(user.id).state == PAUSED  # operator intent sticks


class TestFairShare:
    @staticmethod
    def _rec(cid, weight=1.0, priority=0, min_slots=1, demand=None):
        return CampaignRecord(id=cid, name=cid, state=STAGED, weight=weight,
                              priority=priority, min_slots=min_slots,
                              demand=dict(demand or {"default": 8}))

    def test_grants_proportional_to_weight(self):
        recs = [self._rec("a", weight=2.0), self._rec("b", weight=1.0)]
        grants = compute_grants(recs, {"default": 6})
        assert grants["a"]["default"] == 4 and grants["b"]["default"] == 2

    def test_grant_capped_by_demand(self):
        recs = [self._rec("a", weight=9.0, demand={"default": 2}), self._rec("b")]
        grants = compute_grants(recs, {"default": 6})
        assert grants["a"]["default"] == 2   # no use hoarding beyond demand
        assert grants["b"]["default"] == 4   # surplus flows to the other

    def test_priority_class_takes_capacity_first(self):
        recs = [self._rec("lo", weight=100.0), self._rec("hi", priority=1)]
        grants = compute_grants(recs, {"default": 4})
        assert grants["hi"]["default"] == 4
        assert grants["lo"]["default"] == 0
        assert not meets_floor(recs[0], grants["lo"])

    def test_min_slots_floor_evicts_weakest(self):
        recs = [self._rec("a", weight=3.0, min_slots=2),
                self._rec("b", weight=2.0, min_slots=2),
                self._rec("c", weight=1.0, min_slots=2)]
        grants = compute_grants(recs, {"default": 4})
        # 4 slots cannot float three 2-slot floors: the lightest is parked
        # at zero so the survivors both meet theirs.
        assert grants["c"]["default"] == 0
        assert grants["a"]["default"] >= 2 and grants["b"]["default"] >= 2
        assert total_slots(grants["a"]) + total_slots(grants["b"]) == 4
        assert meets_floor(recs[0], grants["a"]) and meets_floor(recs[1], grants["b"])
        assert not meets_floor(recs[2], grants["c"])

    def test_multi_pool_fleet_apportioned_independently(self):
        recs = [self._rec("a", demand={"default": 4, "aux": 1}),
                self._rec("b", demand={"default": 4})]
        grants = compute_grants(recs, {"default": 4, "aux": 2})
        assert grants["a"] == {"default": 2, "aux": 1}
        assert grants["b"] == {"default": 2}


class TestPlaneInProcess:
    def test_rejects_bad_submissions(self, tmp_path):
        plane = ControlPlane(str(tmp_path), {"default": 4})
        with pytest.raises(ValueError, match="invalid campaign spec"):
            plane.submit("this is not even toml [")
        with pytest.raises(ValueError, match="no fleet pool"):
            plane.submit(
                "[[tasks]]\nfn = \"repro.control.workload.workload_task\"\n"
                "pool = \"gpu\"\n[pools.gpu]\nsize = 2\n"
                "[steering]\nthinker = \"repro.control.workload.make_workload\"\n"
                "[steering.kwargs]\nn_tasks = 4\n")
        with pytest.raises(ValueError, match="in_process"):
            plane.submit(_campaign_toml() + "\n[queues]\nbackend = \"pipe\"\n"
                         "[server]\nin_process = false\n")
        assert plane.store.list() == []  # nothing bad was admitted

    def test_concurrent_campaigns_share_fleet_and_finish(self, tmp_path):
        plane = ControlPlane(str(tmp_path), {"default": 4}, tick_s=0.1).start()
        try:
            a = plane.submit(_campaign_toml(n_tasks=24, weight=2.0), name="heavy")
            b = plane.submit(_campaign_toml(n_tasks=24, weight=1.0), name="light")
            _wait(lambda: all(plane.store.get(c.id).state == DONE for c in (a, b)),
                  timeout=90, msg="both campaigns done")
        finally:
            plane.stop()
        for rec in (a, b):
            idx = _journal_indices(plane.store, rec.id)
            assert sorted(set(idx)) == list(range(24))
            assert len(idx) == 24  # exactly-once: no duplicate journal lines
        # fair share integrated actual vs expected slot-seconds per weight
        # (both demand the whole pool, so the run was contended)
        acct = plane.accounting.report()
        assert set(acct) >= {a.id, b.id}
        for cid in (a.id, b.id):
            assert acct[cid]["contended_s"] > 0

    def test_preemption_checkpoints_and_resumes(self, tmp_path):
        plane = ControlPlane(str(tmp_path), {"default": 2}, tick_s=0.1).start()
        try:
            lo = plane.submit(
                _campaign_toml(n_tasks=40, n_parallel=2, task_s=0.05,
                               pool_size=2, checkpoint_s=0.2),
                name="background")
            _wait(lambda: plane.store.get(lo.id).state == RUNNING,
                  timeout=30, msg="background campaign running")
            _wait(lambda: len(_journal_indices(plane.store, lo.id)) >= 3,
                  timeout=30, msg="background campaign made progress")
            # A priority-1 campaign demanding the whole fleet preempts it.
            hi = plane.submit(
                _campaign_toml(n_tasks=8, n_parallel=2, pool_size=2,
                               priority=1, min_slots=2),
                name="urgent")
            _wait(lambda: plane.store.get(lo.id).state == PAUSED,
                  timeout=30, msg="background campaign preempted")
            pre = _journal_indices(plane.store, lo.id)
            assert pre and len(pre) < 40
            # checkpoint exists: pause is checkpoint + release, not kill
            ckpts = [f for f in os.listdir(plane.store.state_dir(lo.id))
                     if f.endswith(".pkl")]
            assert ckpts, "preemption pause must leave a checkpoint"
            _wait(lambda: plane.store.get(hi.id).state == DONE,
                  timeout=60, msg="urgent campaign done")
            _wait(lambda: plane.store.get(lo.id).state == DONE,
                  timeout=90, msg="background campaign resumed and done")
        finally:
            plane.stop()
        assert plane.store.get(lo.id).resumed >= 1
        idx = _journal_indices(plane.store, lo.id)
        assert sorted(set(idx)) == list(range(40))
        assert len(idx) == 40  # resume re-lost nothing, re-ran nothing
        hi_idx = _journal_indices(plane.store, hi.id)
        assert sorted(set(hi_idx)) == list(range(8))

    def test_user_pause_survives_ticks_until_resume(self, tmp_path):
        plane = ControlPlane(str(tmp_path), {"default": 2}, tick_s=0.1).start()
        try:
            rec = plane.submit(_campaign_toml(n_tasks=60, n_parallel=2,
                                              task_s=0.05, pool_size=2))
            _wait(lambda: plane.store.get(rec.id).state == RUNNING,
                  timeout=30, msg="campaign running")
            plane.pause(rec.id)
            assert plane.store.get(rec.id).state == PAUSED
            time.sleep(0.5)  # several ticks: a user pause must not re-stage
            assert plane.store.get(rec.id).state == PAUSED
            plane.resume(rec.id)
            _wait(lambda: plane.store.get(rec.id).state == DONE,
                  timeout=90, msg="campaign done after resume")
        finally:
            plane.stop()
        idx = _journal_indices(plane.store, rec.id)
        assert sorted(set(idx)) == list(range(60)) and len(idx) == 60


class TestHTTPAPI:
    def test_routes_and_error_mapping(self, tmp_path):
        plane = ControlPlane(str(tmp_path), {"default": 4}, tick_s=0.1).start()
        api = ControlServer(plane).start()
        try:
            def get(path):
                with urllib.request.urlopen(api.url + path, timeout=10) as r:
                    return json.loads(r.read())

            def post(path, body=b""):
                req = urllib.request.Request(api.url + path, data=body, method="POST")
                with urllib.request.urlopen(req, timeout=10) as r:
                    return r.status, json.loads(r.read())

            assert get("/healthz")["ok"] is True
            assert get("/fleet")["fleet"] == {"default": 4}

            status, rec = post("/campaigns?name=via-http",
                               _campaign_toml(n_tasks=8).encode())
            assert status == 201 and rec["name"] == "via-http"
            assert get(f"/campaigns/{rec['id']}")["id"] == rec["id"]
            assert any(c["id"] == rec["id"] for c in get("/campaigns")["campaigns"])

            with pytest.raises(urllib.error.HTTPError) as err:
                post("/campaigns", b"not toml [")
            assert err.value.code == 400
            with pytest.raises(urllib.error.HTTPError) as err:
                get("/campaigns/doesnotexist")
            assert err.value.code == 404

            _wait(lambda: plane.store.get(rec["id"]).state == DONE,
                  timeout=60, msg="http-submitted campaign done")
        finally:
            api.stop()
            plane.stop()


class TestRemoteSiteControlChannel:
    def test_resize_round_trips_to_spawned_server(self, tmp_path):
        """The PR5 follow-on: a resize request crosses the process
        boundary to a spawned ProcessTaskServer, which clamps, resizes,
        acks, and records pool_resize in its own event log."""
        from repro.app import (
            AppSpec, ColmenaApp, ObserveSpec, PoolSpec, QueueSpec, ServerSpec,
        )
        from repro.app import TaskDef
        from repro.control import workload_task

        parent_log = str(tmp_path / "events.jsonl")
        child_log = str(tmp_path / "events.server.jsonl")
        app = ColmenaApp(AppSpec(
            tasks=[TaskDef(fn=workload_task, method="workload_task")],
            queues=QueueSpec(backend="pipe"),
            pools={"default": PoolSpec("default", 2, min_size=1, max_size=6)},
            server=ServerSpec(in_process=False),
            observe=ObserveSpec(jsonl_path=parent_log),
        ))
        with app.run(timeout=60) as handle:
            ack = handle.queues.request_resize("default", 4, timeout=30)
            assert ack is not None and ack.ok, ack
            assert ack.detail == {"old": 2, "new": 4}
            # clamped to the spec band, acked with the effective size
            ack2 = handle.queues.request_resize("default", 99, timeout=30)
            assert ack2 is not None and ack2.ok and ack2.detail["new"] == 6
            # the channel still delivers work after control traffic
            handle.queues.send_inputs(5, method="workload_task")
            r = handle.queues.get_result(timeout=30)
            assert r is not None and r.success and r.value == 16
        # the spawned site recorded the resize in its own event log
        resizes = []
        with open(child_log) as f:
            for line in f:
                try:
                    ev = json.loads(line)
                except ValueError:
                    continue
                if ev.get("kind") == "pool_resize":
                    resizes.append(ev)
        assert any(ev.get("value") == 4.0 for ev in resizes), resizes

    def test_remote_pool_proxy_drives_resize(self):
        """The ElasticScaler-facing proxy: ``resize`` round-trips the
        control channel and mirrors the acked size; a dead site (no ack)
        reports no change instead of wedging the scaler."""
        from repro.app import AppSpec, ColmenaApp, PoolSpec, QueueSpec, ServerSpec
        from repro.app import TaskDef
        from repro.control import workload_task
        from repro.core.app import RemotePool

        spec = PoolSpec("default", 2, min_size=1, max_size=4)
        app = ColmenaApp(AppSpec(
            tasks=[TaskDef(fn=workload_task, method="workload_task")],
            queues=QueueSpec(backend="pipe"),
            pools={"default": spec},
            server=ServerSpec(in_process=False),
        ))
        with app.run(timeout=60) as handle:
            proxy = RemotePool(handle.queues, spec)
            assert proxy.n_workers == 2
            old, new = proxy.resize(3)
            assert (old, new) == (2, 3)
            assert proxy.n_workers == 3
        # with no site listening there is no ack: no change, no hang
        from repro.core import PipeColmenaQueues

        dead = RemotePool(PipeColmenaQueues(), spec, ack_timeout_s=0.3)
        assert dead.resize(4) == (2, 2)


@pytest.mark.slow
class TestDaemonCrashResume:
    def test_sigkill_mid_run_then_auto_resume(self, tmp_path):
        """SIGKILL the serve daemon while campaigns are mid-flight; a
        restart on the same root must auto-resume every non-done campaign
        and finish all of them with exactly-once journals."""
        root = str(tmp_path / "root")
        fleet = tmp_path / "fleet.toml"
        fleet.write_text("[pools.default]\nsize = 4\n")
        port_file = tmp_path / "port"
        env = dict(os.environ, PYTHONPATH=SRC)

        def serve():
            return subprocess.Popen(
                [sys.executable, "-m", "repro.control", "serve",
                 "--root", root, "--fleet", str(fleet),
                 "--port-file", str(port_file), "--tick", "0.1"],
                env=env, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL,
            )

        def url():
            return f"http://127.0.0.1:{port_file.read_text().strip()}"

        def get(path):
            with urllib.request.urlopen(url() + path, timeout=10) as r:
                return json.loads(r.read())

        proc = serve()
        try:
            _wait(port_file.exists, timeout=60, msg="daemon port file")
            body = _campaign_toml(n_tasks=40, n_parallel=4, task_s=0.05,
                                  checkpoint_s=0.2).encode()
            ids = []
            for name in ("alpha", "beta"):
                req = urllib.request.Request(
                    url() + f"/campaigns?name={name}", data=body, method="POST")
                with urllib.request.urlopen(req, timeout=30) as r:
                    ids.append(json.loads(r.read())["id"])

            store = StateStore(root)

            def mid_flight():
                return all(
                    len(_journal_indices(store, cid)) >= 4 for cid in ids
                ) and not all(
                    StateStore(root).get(cid).state == DONE for cid in ids
                )

            _wait(mid_flight, timeout=60, msg="campaigns mid-flight")

            from repro.chaos import kill_control_plane
            assert kill_control_plane(proc) == proc.pid

            port_file.unlink()
            proc = serve()
            _wait(port_file.exists, timeout=60, msg="daemon restart port file")
            _wait(lambda: all(c["state"] == DONE
                              for c in get("/campaigns")["campaigns"]),
                  timeout=120, msg="all campaigns done after resume")

            campaigns = get("/campaigns")["campaigns"]
            assert {c["id"] for c in campaigns} == set(ids)
            assert all(c["resumed"] >= 1 for c in campaigns)
            store = StateStore(root)
            for cid in ids:
                idx = _journal_indices(store, cid)
                assert sorted(set(idx)) == list(range(40)), f"lost results in {cid}"
                assert len(idx) == len(set(idx)), f"duplicate results in {cid}"
        finally:
            if proc.poll() is None:
                proc.send_signal(signal.SIGTERM)
                try:
                    proc.wait(timeout=20)
                except subprocess.TimeoutExpired:
                    proc.kill()
