"""End-to-end system tests: a full Colmena campaign steering real JAX
computations — the paper's molecular-design pattern in miniature, plus
the steering templates."""

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BatchRetrainThinker,
    Campaign,
    ConstantInflightThinker,
    FailureInjector,
    InMemoryConnector,
    LocalColmenaQueues,
    PriorityQueueThinker,
    ResourceRequest,
    RetryPolicy,
    Store,
    TaskServer,
    WorkerPool,
    stateful_task,
)


def _quadratic_landscape(x: np.ndarray) -> float:
    """Synthetic 'simulation': expensive scalar property of a molecule."""
    time.sleep(0.01)
    return float(-np.sum((x - 0.3) ** 2))


@stateful_task
def _train_surrogate(X, y, registry=None):
    """Ridge-regression surrogate via jnp (cached design matrix in registry)."""
    X = jnp.asarray(X)
    y = jnp.asarray(y)
    XtX = X.T @ X + 1e-3 * jnp.eye(X.shape[1])
    w = jnp.linalg.solve(XtX, X.T @ y)
    registry["model_version"] = registry.get("model_version", 0) + 1
    return np.asarray(w)


class MolDesign(BatchRetrainThinker):
    """Simulate -> retrain surrogate -> steer further sampling."""

    def __init__(self, queues, dim=4, **kw):
        super().__init__(queues, **kw)
        self.dim = dim
        self.rng = np.random.default_rng(0)
        self.surrogate = None
        self.best = -np.inf

    def simulate_args(self):
        if self.surrogate is None:
            x = self.rng.uniform(-1, 1, self.dim)
        else:   # exploit the surrogate: move toward predicted optimum
            x = np.clip(self.rng.normal(0.0, 0.3, self.dim) + 0.5 * self.surrogate[: self.dim], -1, 1)
        return (x,)

    def on_simulation(self, result):
        self.best = max(self.best, result.value)

    def make_train_task(self):
        X = np.stack([np.asarray(r.args[0]) for r in self.database])
        y = np.asarray([r.value for r in self.database])
        return (X, y), {}

    def on_train(self, result):
        if result.success:
            self.surrogate = np.asarray(result.value)


class TestEndToEndCampaign:
    def test_molecular_design_campaign(self, tmp_path):
        store = Store("e2e", InMemoryConnector())
        q = LocalColmenaQueues(topics=["simulate", "train"], proxystore=store,
                               proxy_threshold=256)
        thinker = MolDesign(q, n_slots=4, retrain_after=5, max_results=40, ml_slots=1)
        server = TaskServer(
            q, {"simulate": _quadratic_landscape, "train": _train_surrogate},
            pools={"simulate": WorkerPool("simulate", 3), "ml": WorkerPool("ml", 1),
                   "default": WorkerPool("default", 1)},
            injector=FailureInjector(task_failure_rate=0.05, seed=3),
            retry=RetryPolicy(max_retries=8),
        )
        campaign = Campaign(thinker, server, state_dir=str(tmp_path),
                            checkpoint_interval_s=0.2)
        report = campaign.run(timeout=60)
        assert report.completed
        assert len(thinker.database) >= 40
        assert thinker.train_rounds >= 1         # AI actually retrained
        assert thinker.surrogate is not None     # and steered
        assert report.checkpoints_written >= 1
        assert thinker.best > -4.0

    def test_constant_inflight_preserves_order_independence(self):
        q = LocalColmenaQueues()
        server = TaskServer(q, {"sq": lambda x: x * x}, n_workers=3).start()
        work = [((i,), {}) for i in range(12)]
        t = ConstantInflightThinker(q, work, method="sq", n_parallel=3)
        t.run(timeout=20)
        assert sorted(r.value for r in t.results) == [i * i for i in range(12)]
        server.stop()

    def test_priority_queue_thinker_orders_work(self):
        q = LocalColmenaQueues()
        order = []
        server = TaskServer(q, {"f": lambda x: order.append(x) or x}, n_workers=1).start()

        class T(PriorityQueueThinker):
            pass

        t = T(q, method="f", n_slots=1, max_tasks=4)
        for prio, val in [(3.0, "low"), (0.0, "hi1"), (0.5, "hi2"), (2.0, "mid")]:
            t.push((val,), priority=prio)
        t.run(timeout=20)
        assert order[0] == "hi1" and order[1] == "hi2"
        server.stop()

    def test_act_on_completion_beats_result_arrival(self):
        """Completion notices enable reacting before (possibly large)
        result payloads resolve — the paper's key latency optimization."""
        store = Store("aoc", InMemoryConnector())
        q = LocalColmenaQueues(proxystore=store, proxy_threshold=64)
        server = TaskServer(q, {"big": lambda: np.zeros(100_000)}, n_workers=1).start()
        q.send_inputs(method="big")
        notice = q.get_completion(timeout=5)
        assert notice is not None and notice.success
        r = q.get_result(timeout=5)
        assert r.time.completion_notified <= r.time.returned
        assert not r.value.is_resolved     # payload still lazy on arrival
        server.stop()
