"""Unit tests: task queues, thinker agents, resource counter."""

import threading
import time

import numpy as np
import pytest

from repro.core import (
    BaseThinker,
    InMemoryConnector,
    KillSignal,
    LocalColmenaQueues,
    PipeColmenaQueues,
    Proxy,
    ResourceCounter,
    Store,
    agent,
    event_responder,
    result_processor,
    task_submitter,
)


def _serve_n(queues, fns, n):
    """Minimal inline server: run n tasks synchronously."""
    for _ in range(n):
        task = queues.get_task(timeout=2)
        assert task is not None
        task.mark("compute_started")
        try:
            task.set_success(fns[task.method](*task.args, **task.kwargs))
        except Exception as e:  # noqa: BLE001
            from repro.core import FailureKind

            task.set_failure(FailureKind.EXCEPTION, str(e))
        task.mark("compute_ended")
        queues.send_result(task)


class TestQueues:
    @pytest.mark.parametrize("qcls", [LocalColmenaQueues, PipeColmenaQueues])
    def test_roundtrip(self, qcls):
        q = qcls(topics=["a"])
        tid = q.send_inputs(2, 3, method="add", topic="a")
        _serve_n(q, {"add": lambda x, y: x + y}, 1)
        r = q.get_result(topic="a", timeout=2)
        assert r.task_id == tid and r.success and r.value == 5
        assert r.timing.compute is not None

    def test_topics_independent(self):
        q = LocalColmenaQueues(topics=["t1", "t2"])
        q.send_inputs(1, method="f", topic="t1")
        q.send_inputs(2, method="f", topic="t2")
        _serve_n(q, {"f": lambda x: x}, 2)
        r2 = q.get_result(topic="t2", timeout=2)
        r1 = q.get_result(topic="t1", timeout=2)
        assert r1.value == 1 and r2.value == 2

    def test_completion_notice_before_result(self):
        q = LocalColmenaQueues()
        q.send_inputs(7, method="f")
        _serve_n(q, {"f": lambda x: x}, 1)
        notice = q.get_completion(timeout=2)
        assert notice is not None and notice.success
        r = q.get_result(timeout=2)
        assert r.value == 7
        # act-on-completion: notice timestamp precedes result return
        assert r.time.completion_notified <= r.time.returned

    def test_kill_signal(self):
        q = LocalColmenaQueues()
        q.send_kill_signal()
        with pytest.raises(KillSignal):
            q.get_task(timeout=2)

    def test_auto_proxy_large_results(self):
        store = Store("q-test", InMemoryConnector())
        q = LocalColmenaQueues(proxystore=store, proxy_threshold=100)
        q.send_inputs(np.zeros(1000), method="f")
        task = q.get_task(timeout=2)
        assert isinstance(task.args[0], Proxy)   # input auto-proxied
        task.mark("compute_started")
        task.set_success(np.ones(1000))
        task.mark("compute_ended")
        q.send_result(task)
        r = q.get_result(timeout=2)
        assert isinstance(r.value, Proxy)        # output auto-proxied
        assert np.allclose(r.value.resolve(), np.ones(1000))
        assert q.metrics.proxied_bytes >= 16000

    def test_timeout_returns_none(self):
        q = LocalColmenaQueues()
        assert q.get_result(timeout=0.05) is None
        assert q.get_task(timeout=0.05) is None


class TestResourceCounter:
    def test_acquire_release(self):
        rc = ResourceCounter(4)
        assert rc.acquire("default", 3, timeout=0.1)
        assert not rc.acquire("default", 2, timeout=0.1)
        rc.release("default", 3)
        assert rc.available("default") == 4

    def test_reallocate(self):
        rc = ResourceCounter(8, pools=["sim", "ml"])
        assert rc.available("sim") == 8
        assert rc.reallocate("sim", "ml", 3, timeout=0.5)
        assert rc.available("ml") == 3 and rc.available("sim") == 5

    def test_elastic_grow_shrink(self):
        rc = ResourceCounter(2)
        rc.grow("default", 4)
        assert rc.total_slots == 6 and rc.available("default") == 6
        assert rc.shrink("default", 3, timeout=0.5)
        assert rc.total_slots == 3

    def test_blocking_acquire_wakes(self):
        rc = ResourceCounter(1)
        assert rc.acquire("default", 1, timeout=0.1)
        ok = []

        def waiter():
            ok.append(rc.acquire("default", 1, timeout=2))

        t = threading.Thread(target=waiter)
        t.start()
        time.sleep(0.05)
        rc.release("default", 1)
        t.join()
        assert ok == [True]


class TestThinkerAgents:
    def test_all_agent_types_cooperate(self):
        q = LocalColmenaQueues()
        seen = []

        class T(BaseThinker):
            def __init__(self):
                super().__init__(q, ResourceCounter(2))
                self.submitted = 0

            @agent(startup=True)
            def boot(self):
                seen.append("boot")

            @task_submitter(task_type="default", n_slots=1)
            def submit(self):
                self.submitted += 1
                self.queues.send_inputs(self.submitted, method="echo")
                if self.submitted >= 3:
                    self.set_event("enough")

            @result_processor()
            def recv(self, result):
                seen.append(("result", result.value))
                self.rec.release("default", 1)

            @event_responder(event_name="enough")
            def finish(self):
                time.sleep(0.1)  # let results drain
                self.done.set()

        thinker = T()
        server = threading.Thread(
            target=_serve_n, args=(q, {"echo": lambda x: x}, 3), daemon=True
        )
        server.start()
        thinker.run(timeout=10)
        assert "boot" in seen
        assert len([s for s in seen if isinstance(s, tuple)]) >= 2

    def test_critical_agent_exit_sets_done(self):
        q = LocalColmenaQueues()

        class T(BaseThinker):
            @agent
            def main(self):
                time.sleep(0.02)

        t = T(q)
        t.run(timeout=5)
        assert t.done.is_set()

    def test_agent_exception_propagates(self):
        q = LocalColmenaQueues()

        class T(BaseThinker):
            @agent
            def main(self):
                raise ValueError("boom")

        with pytest.raises(RuntimeError):
            T(q).run(timeout=5)
