"""repro.app: declarative composition + lifecycle edges.

Covers the app-layer acceptance surface: context-manager teardown on
agent exceptions, double-start/stop idempotency, pipe-backend parity
with local, resume-from-checkpoint through ``ColmenaApp`` (not just raw
``Campaign``), the task registry's pool/batch routing, driver mode, and
the kill-sentinel shutdown path + checkpoint retention satellites.
"""

import os
import time

import numpy as np
import pytest

from repro.app import (
    AppSpec,
    CampaignSpec,
    ColmenaApp,
    FabricSpec,
    ObserveSpec,
    QueueSpec,
    SteeringSpec,
    TaskDef,
    task,
)
from repro.core import (
    BaseThinker,
    Campaign,
    ConstantInflightThinker,
    LocalColmenaQueues,
    PipeColmenaQueues,
    ResourceCounter,
    ServerMetrics,
    agent,
    result_processor,
)


def _echo(x):
    return x


def _double(x):
    return 2 * x


def _triple(x):
    return 3 * x


class CountingThinker(BaseThinker):
    """Submit-on-completion thinker with checkpointable progress."""

    def __init__(self, queues, target=8, n_parallel=2):
        super().__init__(queues, ResourceCounter(n_parallel))
        self.target = target
        self.count = 0

    @agent(startup=True)
    def boot(self):
        for _ in range(self.rec.total_slots):
            self.queues.send_inputs(1, method="echo")

    @result_processor()
    def recv(self, result):
        self.count += 1
        if self.count >= self.target:
            self.done.set()
        else:
            self.queues.send_inputs(1, method="echo")

    def get_state(self):
        return {"count": self.count}

    def set_state(self, state):
        self.count = state.get("count", 0)


class CrashyThinker(BaseThinker):
    @agent
    def main(self):
        raise ValueError("boom")


class TestComposition:
    def test_basic_run_and_report(self):
        app = ColmenaApp(AppSpec(
            tasks={"echo": _echo},
            pools={"default": 2},
            steering=SteeringSpec(CountingThinker, dict(target=6)),
        ))
        with app.run(timeout=30) as handle:
            assert handle.wait(30)
        assert handle.thinker.count == 6
        assert app.report.completed
        assert app.report.server_metrics["tasks_completed"] >= 6
        rep = app.observe_report()
        assert rep["stage_counts"]["completed"] >= 6

    def test_task_registry_pool_and_timeout_defaults(self):
        @task(pool="special", timeout_s=7.5)
        def special(x):
            return x + 1

        app = ColmenaApp(AppSpec(
            tasks=[special],
            pools={"special": 1, "default": 1},
        ))
        with app.run() as handle:
            handle.queues.send_inputs(1, method="special")
            r = handle.queues.get_result(timeout=10)
        assert r.success and r.value == 2
        # the registry's defaults were applied server-side
        assert r.resources.pool == "special"
        assert r.resources.timeout_s == 7.5

    def test_task_registry_batch_flag(self):
        app = ColmenaApp(AppSpec(
            tasks=[TaskDef(fn=_echo, method="echo", batch=True),
                   TaskDef(fn=_double, method="double")],
        ))
        app.build()
        assert app.server.batching is not None
        assert app.server.batching.methods == ("echo",)

    def test_duplicate_methods_rejected(self):
        with pytest.raises(ValueError, match="duplicate"):
            ColmenaApp(AppSpec(tasks=[TaskDef(fn=_echo, method="m"),
                                      TaskDef(fn=_double, method="m")]))

    def test_driver_mode(self):
        """steering=None: the caller drives the composed queues."""
        app = ColmenaApp(AppSpec(tasks={"double": _double}, pools={"default": 2}))
        with app.run() as handle:
            for i in range(5):
                handle.queues.send_inputs(i, method="double")
            vals = sorted(handle.queues.get_result(timeout=10).value for _ in range(5))
        assert vals == [0, 2, 4, 6, 8]
        assert app.report.completed

    def test_fabric_composition_auto_proxies(self):
        app = ColmenaApp(AppSpec(
            tasks={"echo": _echo},
            fabric=FabricSpec(connector="memory", threshold=100),
        ))
        with app.run() as handle:
            handle.queues.send_inputs(np.zeros(1000), method="echo")
            r = handle.queues.get_result(timeout=10)
        assert r.success
        assert handle.queues.metrics.proxied_bytes >= 8000
        assert app.store is not None

    def test_rebind_event_log(self):
        from repro.observe import EventLog

        app = ColmenaApp(AppSpec(tasks={"echo": _echo}))
        with app.run() as handle:
            handle.queues.send_inputs(1, method="echo")
            assert handle.queues.get_result(timeout=10).success
            first = app.event_log
            n_before = len(first.events())
            fresh = EventLog()
            app.rebind_event_log(fresh)
            handle.queues.send_inputs(2, method="echo")
            assert handle.queues.get_result(timeout=10).success
        assert len(fresh.events()) > 0
        assert len(first.events()) == n_before  # old log stopped growing


class TestLifecycleEdges:
    def test_teardown_on_agent_exception(self):
        app = ColmenaApp(AppSpec(
            tasks={"echo": _echo},
            steering=SteeringSpec(CrashyThinker),
        ))
        with pytest.raises(RuntimeError, match="agent"):
            with app.run(timeout=10) as handle:
                handle.wait(10)
        # the crash was contained: the stack still tore down in order
        assert app.report is not None and not app.report.completed
        assert app.server._stop.is_set()
        assert app.thinker_exception is not None

    def test_stop_safe_after_failed_build(self):
        """A build error mid-start must not be masked by stop()."""
        app = ColmenaApp(AppSpec(
            tasks={"echo": _echo},
            fabric=FabricSpec(connector="no-such-connector"),
        ))
        with pytest.raises(ValueError, match="connector"):
            with app.run():
                pass  # never reached: __enter__ raises from build()
        app.stop()  # partially-built stack: must not raise
        assert not app.report.completed

    def test_body_exception_still_stops_stack(self):
        app = ColmenaApp(AppSpec(tasks={"echo": _echo}))
        with pytest.raises(KeyError):
            with app.run():
                raise KeyError("user code failed")
        assert app.report is not None
        assert app.server._stop.is_set()

    def test_double_start_and_stop_idempotent(self):
        app = ColmenaApp(AppSpec(
            tasks={"echo": _echo},
            steering=SteeringSpec(CountingThinker, dict(target=4)),
        ))
        app.start(timeout=30)
        app.start(timeout=30)          # no-op
        assert app.wait(30)
        report = app.stop()
        assert app.stop() is report    # second stop returns the same report
        assert report.completed

    def test_stop_before_start_is_noop(self):
        app = ColmenaApp(AppSpec(
            tasks={"echo": _echo},
            steering=SteeringSpec(CountingThinker, dict(target=3)),
        ))
        assert app.stop() is None       # nothing ran; must not poison start
        report = app.execute(timeout=30)
        assert report.completed and app.thinker.count == 3

    def test_driver_mode_rejects_reallocator(self):
        with pytest.raises(ValueError, match="reallocator"):
            AppSpec(tasks={"echo": _echo},
                    observe=ObserveSpec(reallocator="greedy"))

    def test_rebind_event_log_repoints_reallocator(self):
        from repro.observe import EventLog

        app = ColmenaApp(AppSpec(
            tasks={"echo": _echo},
            steering=SteeringSpec(CountingThinker, dict(target=2)),
            observe=ObserveSpec(reallocator="greedy"),
        ))
        app.build()
        stale_agg = app.reallocator.metrics
        fresh = EventLog()
        app.rebind_event_log(fresh)
        assert app.reallocator.event_log is fresh
        assert app.reallocator.metrics is not stale_agg     # fresh aggregator
        assert app.reallocator._backlog == app.reallocator.metrics.backlog
        app.stop()

    def test_restart_refused(self):
        app = ColmenaApp(AppSpec(tasks={"echo": _echo}))
        with app.run():
            pass
        with pytest.raises(RuntimeError, match="already ran"):
            app.start()

    def test_pipe_backend_parity_with_local(self):
        """Porting local -> pipe is one spec field; results must match."""
        outputs = {}
        for backend in ("local", "pipe"):
            work = [((i,), {}) for i in range(8)]
            app = ColmenaApp(AppSpec(
                tasks={"double": _double},
                queues=QueueSpec(backend=backend),
                pools={"default": 2},
                steering=SteeringSpec(ConstantInflightThinker, dict(
                    work=work, method="double", n_parallel=2)),
            ))
            with app.run(timeout=60) as handle:
                assert handle.wait(60)
                outputs[backend] = sorted(r.value for r in handle.thinker.results)
            assert app.report.completed
        assert outputs["local"] == outputs["pipe"] == [2 * i for i in range(8)]

    def test_two_pool_process_server_parity(self):
        """A two-pool campaign must produce identical results whether the
        named pools live in this process or are rebuilt from PoolSpecs
        inside a spawned server (the federated multi-resource shape)."""
        from repro.app import PoolSpec, ServerSpec

        outputs = {}
        for backend, in_process in (("local", True), ("pipe", False)):
            app = ColmenaApp(AppSpec(
                tasks=[TaskDef(fn=_double, method="double", pool="cpu"),
                       TaskDef(fn=_triple, method="triple", pool="accel")],
                queues=QueueSpec(backend=backend),
                pools={"cpu": 2, "accel": PoolSpec("accel", 1, warm_capacity=8)},
                server=ServerSpec(in_process=in_process),
            ))
            with app.run(timeout=60) as handle:
                for i in range(4):
                    handle.queues.send_inputs(i, method="double")
                    handle.queues.send_inputs(i, method="triple")
                got = sorted(
                    handle.queues.get_result(timeout=60).value for _ in range(8)
                )
            outputs[backend] = got
            assert app.report.completed
        expect = sorted([2 * i for i in range(4)] + [3 * i for i in range(4)])
        assert outputs["local"] == outputs["pipe"] == expect

    def test_fabric_knobs_cross_process_boundary(self):
        """Warm/prefetch knobs ride inside PoolSpecs now; the old
        refusal for in_process=False is gone."""
        from repro.app import ServerSpec

        app = ColmenaApp(AppSpec(
            tasks={"echo": _echo},
            queues=QueueSpec(backend="pipe"),
            fabric=FabricSpec(connector="file", warm_capacity=4, prefetch=False),
            server=ServerSpec(in_process=False),
        ))
        with app.run(timeout=60) as handle:
            handle.queues.send_inputs(7, method="echo")
            r = handle.queues.get_result(timeout=30)
        assert r is not None and r.success and r.value == 7

    def test_resume_from_checkpoint_through_app(self, tmp_path):
        state_dir = str(tmp_path)

        def make_app(target):
            return ColmenaApp(AppSpec(
                tasks={"echo": _echo},
                pools={"default": 2},
                steering=SteeringSpec(CountingThinker, dict(target=target)),
                campaign=CampaignSpec(state_dir=state_dir,
                                      checkpoint_interval_s=0.5),
            ))

        first = make_app(target=4)
        first.execute(timeout=30)
        assert first.thinker.count == 4
        assert first.report.checkpoints_written >= 1

        # Same entry point, same spec shape: resumes at count=4, so only
        # 4 more results are consumed to reach 8.
        second = make_app(target=8)
        second.execute(timeout=30)
        assert second.report.resumed_from is not None
        assert second.thinker.count == 8
        assert second.report.server_metrics["tasks_completed"] <= 6  # 4 resumed + ~2 in flight


class TestKillSentinelShutdown:
    def test_wake_sentinels_unblock_pops(self):
        for qcls in (LocalColmenaQueues, PipeColmenaQueues):
            q = qcls(topics=["a"])
            q.wake_result_waiters({("a", "result"): 1, ("a", "completion"): 1})
            t0 = time.monotonic()
            # Bounded pops treat a stale sentinel as noise and keep
            # waiting out the timeout; blocking pops (the result-processor
            # path) return immediately — the sentinel IS the wakeup.
            assert q.get_result(topic="a", timeout=0.2) is None
            assert q.get_completion(topic="a", timeout=0.2) is None
            assert time.monotonic() - t0 < 2.0

    def test_stale_sentinel_does_not_hide_real_results(self):
        """A leftover sentinel must not make a bounded drain miss results
        queued behind it (late in-flight overshoot after shutdown)."""
        q = LocalColmenaQueues(topics=["a"])
        q.wake_result_waiters({("a", "result"): 1})
        q.send_inputs(5, method="echo", topic="a")
        t = q.get_task(timeout=2)
        t.mark("compute_started")
        t.set_success(10)
        t.mark("compute_ended")
        q.send_result(t)
        r = q.get_result(topic="a", timeout=5)
        assert r is not None and r.value == 10

    def test_blocking_pop_wakes_on_sentinel(self):
        q = LocalColmenaQueues(topics=["a"])
        got = []

        def blocked_pop():
            got.append(q.get_result(topic="a", timeout=None))

        import threading
        th = threading.Thread(target=blocked_pop, daemon=True)
        th.start()
        time.sleep(0.05)
        assert th.is_alive()  # parked in the blocking pop
        q.wake_result_waiters({("a", "result"): 1})
        th.join(timeout=2)
        assert not th.is_alive() and got == [None]

    def test_thinker_shutdown_not_bounded_by_pop_timeout(self):
        q = LocalColmenaQueues()

        class T(BaseThinker):
            @agent
            def main(self):
                time.sleep(0.05)

            @result_processor()
            def recv(self, result):
                pass

        t = T(q)
        t0 = time.monotonic()
        t.run(timeout=10)
        elapsed = time.monotonic() - t0
        # The critical agent exits at ~0.05 s; the processor must wake on
        # the shutdown sentinel, not a pop timeout (formerly 0.2 s).
        assert elapsed < 1.0
        for th in t._threads:
            assert not th.is_alive()


class _StubServer:
    def __init__(self):
        self.metrics = ServerMetrics()


class TestCheckpointRetention:
    def test_only_newest_checkpoints_retained(self, tmp_path):
        camp = Campaign(thinker=object(), server=_StubServer(),
                        state_dir=str(tmp_path), name="c")
        for _ in range(10):
            camp.checkpoint()
        files = sorted(p for p in os.listdir(tmp_path) if p.endswith(".pkl"))
        assert files == [f"c-state-{i:06d}.pkl" for i in range(6, 10)]

    def test_resume_continues_step_numbering(self, tmp_path):
        camp = Campaign(thinker=object(), server=_StubServer(),
                        state_dir=str(tmp_path), name="c")
        for _ in range(5):
            camp.checkpoint()
        resumed = Campaign(thinker=object(), server=_StubServer(),
                           state_dir=str(tmp_path), name="c")
        assert resumed.try_resume()
        assert resumed.checkpoints_written == 5  # next write is step 5
        resumed.checkpoint()
        assert os.path.exists(os.path.join(str(tmp_path), "c-state-000005.pkl"))
