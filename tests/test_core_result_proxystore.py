"""Unit tests: Result ledger + ProxyStore data fabric."""

import pickle
import time

import numpy as np
import pytest

from repro.core import (
    FailureKind,
    FileConnector,
    InMemoryConnector,
    Proxy,
    ResourceRequest,
    Result,
    Store,
    apply_threshold,
    prefetch_all,
    resolve_all,
)
from repro.core.proxystore import get_store
from repro.core.serialization import object_nbytes


class TestResult:
    def test_timing_derivation(self):
        r = Result(method="f", args=(1,))
        r.mark("created")
        r.mark("compute_started")
        time.sleep(0.01)
        r.mark("compute_ended")
        r.mark("result_received")
        r.mark("decision_made")
        t = r.finalize_timings()
        assert t.compute >= 0.01
        assert t.dispatch >= 0
        assert t.reaction is not None and t.decision is not None

    def test_retry_clone_fresh(self):
        r = Result(method="f", args=(1, 2), kwargs={"a": 3}, topic="t")
        r.set_failure(FailureKind.WORKER_DIED, "boom")
        c = r.clone_for_retry()
        assert c.retries == 1
        assert c.task_id != r.task_id
        assert c.args == (1, 2) and c.kwargs == {"a": 3} and c.topic == "t"
        assert c.success is None

    def test_speculative_clone_same_id(self):
        r = Result(method="f")
        c = r.clone_for_speculation()
        assert c.task_id == r.task_id
        assert c.speculative

    def test_success_failure_transitions(self):
        r = Result(method="f")
        r.set_success(42)
        assert r.success and r.value == 42
        r.set_failure(FailureKind.TIMEOUT, "too slow")
        assert not r.success and r.failure is FailureKind.TIMEOUT


class TestProxyStore:
    def test_roundtrip_memory(self):
        store = Store("t1", InMemoryConnector())
        key = store.put({"x": 1})
        assert store.get(key) == {"x": 1}

    def test_proxy_lazy_and_transparent(self):
        store = Store("t2", InMemoryConnector())
        arr = np.arange(10.0)
        p = store.proxy(arr)
        assert not p.is_resolved
        assert p.nbytes == arr.nbytes
        # transparent ops
        assert np.allclose(np.asarray(p), arr)
        assert p.is_resolved
        assert (p + 1)[0] == 1.0
        assert p.shape == (10,)

    def test_proxy_pickles_small(self):
        store = Store("t3", InMemoryConnector())
        big = np.zeros(100_000)
        p = store.proxy(big)
        blob = pickle.dumps(p)
        assert len(blob) < 1000  # control-channel payload stays tiny

    def test_proxy_cross_process_via_file(self, tmp_path):
        store = Store("t4", FileConnector(str(tmp_path)))
        p = store.proxy(np.ones(5))
        blob = pickle.dumps(p)
        # simulate a fresh process: drop the registry entry
        from repro.core import proxystore as ps

        with ps._REGISTRY_LOCK:
            ps._REGISTRY.pop("t4")
        p2 = pickle.loads(blob)
        assert np.allclose(p2.resolve(), np.ones(5))

    def test_threshold_proxying(self):
        store = Store("t5", InMemoryConnector())
        args = (np.zeros(10_000), 5, "small")
        out, moved = apply_threshold(args, store, threshold_bytes=1000)
        assert isinstance(out[0], Proxy)
        assert out[1] == 5 and out[2] == "small"
        assert moved == args[0].nbytes
        resolved = resolve_all(out)
        assert np.allclose(resolved[0], args[0])

    def test_worker_cache_hits(self):
        store = Store("t6", InMemoryConnector(), cache_size=4)
        key = store.put(np.ones(10))
        store.get(key)
        store.get(key)
        assert store.metrics.cache_hits >= 1

    def test_prefetch_overlap(self):
        store = Store("t7", InMemoryConnector())
        p = store.proxy(np.ones(100))
        prefetch_all((p,))
        deadline = time.time() + 2
        while not p.is_resolved and time.time() < deadline:
            time.sleep(0.005)
        assert np.allclose(p.resolve(), np.ones(100))

    def test_evict_after_resolve(self):
        store = Store("t8", InMemoryConnector())
        p = store.proxy(np.ones(3), evict_after_resolve=True)
        p.resolve()
        assert not store.connector.exists(p.key)

    def test_object_nbytes(self):
        assert object_nbytes(np.zeros(10, np.float64)) == 80
        assert object_nbytes(b"abc") == 3
        assert object_nbytes([np.zeros(2), np.zeros(3)]) == 40
