"""The ``repro.analyze`` static-analysis suite + runtime lock sanitizer.

Each rule gets a failing fixture (a minimal source snippet that must be
flagged) and a passing fixture (the corrected idiom that must NOT be
flagged), exercised through the real engine (``analyze_paths`` over a
tmp directory). On top of the per-rule pairs: suppression comments,
baseline round-trip/staleness, the CLI exit codes, the runtime lock
sanitizer (cycle detection, Condition protocol, env install), and the
gate — ``src/repro`` must analyze clean against the committed baseline.
"""

import json
import os
import subprocess
import sys
import textwrap
import threading
import time

import pytest

from repro.analyze import (
    analyze_paths,
    all_checkers,
    load_baseline,
    write_baseline,
)
from repro.analyze import runtime
from repro.analyze.__main__ import main as analyze_main

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_rule(tmp_path, rule, sources):
    """Write ``{filename: snippet}`` fixtures and analyze them with one rule."""
    for name, src in sources.items():
        p = tmp_path / name
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return analyze_paths([str(tmp_path)], rules=[rule])


# ---------------------------------------------------------------------------
# busy-wait
# ---------------------------------------------------------------------------


class TestBusyWait:
    def test_sleep_spin_flagged(self, tmp_path):
        res = run_rule(tmp_path, "busy-wait", {"spin.py": """
            import time

            def drain(state):
                while not state.done:
                    time.sleep(0.05)
        """})
        assert [v.rule for v in res.violations] == ["busy-wait"]
        assert res.violations[0].symbol == "drain"

    def test_event_wait_passes(self, tmp_path):
        res = run_rule(tmp_path, "busy-wait", {"ok.py": """
            def drain(stop):
                while not stop.is_set():
                    stop.wait(0.5)
        """})
        assert res.ok

    def test_short_poll_flagged(self, tmp_path):
        res = run_rule(tmp_path, "busy-wait", {"poll.py": """
            def drain(stop):
                while not stop.is_set():
                    stop.wait(0.02)
        """})
        assert len(res.violations) == 1
        assert res.violations[0].symbol.endswith(":short-poll")

    def test_poll_constant_name_flagged(self, tmp_path):
        res = run_rule(tmp_path, "busy-wait", {"poll.py": """
            _POLL_S = 0.02

            def drain(ev, done):
                while not done.is_set():
                    if ev.wait(timeout=_POLL_S):
                        return True
        """})
        assert len(res.violations) == 1

    def test_inline_suppression(self, tmp_path):
        res = run_rule(tmp_path, "busy-wait", {"poll.py": """
            def sampler(stop):
                while not stop.is_set():
                    stop.wait(0.02)  # analyze: ignore[busy-wait]
        """})
        assert res.ok
        assert len(res.suppressed) == 1

    def test_suppression_on_line_above(self, tmp_path):
        res = run_rule(tmp_path, "busy-wait", {"poll.py": """
            def sampler(stop):
                while not stop.is_set():
                    # analyze: ignore[busy-wait]
                    stop.wait(0.02)
        """})
        assert res.ok and len(res.suppressed) == 1


# ---------------------------------------------------------------------------
# lock-order
# ---------------------------------------------------------------------------

_INVERTED = """
    import threading

    class Pair:
        def __init__(self):
            self._alock = threading.Lock()
            self._block = threading.Lock()

        def ab(self):
            with self._alock:
                with self._block:
                    pass

        def ba(self):
            with self._block:
                with self._alock:
                    pass
"""


class TestLockOrder:
    def test_inverted_order_is_a_cycle(self, tmp_path):
        res = run_rule(tmp_path, "lock-order", {"pair.py": _INVERTED})
        assert len(res.violations) == 1
        v = res.violations[0]
        assert v.symbol == "Pair._alock<->Pair._block"
        assert "ab" not in v.symbol  # symbol is the cycle, sites in message
        assert "pair.py" in v.message

    def test_consistent_order_passes(self, tmp_path):
        res = run_rule(tmp_path, "lock-order", {"pair.py": """
            import threading

            class Pair:
                def __init__(self):
                    self._alock = threading.Lock()
                    self._block = threading.Lock()

                def ab(self):
                    with self._alock:
                        with self._block:
                            pass

                def ab2(self):
                    with self._alock:
                        with self._block:
                            pass
        """})
        assert res.ok

    def test_one_level_call_expansion(self, tmp_path):
        # outer() holds A and calls self.inner() which takes B; other()
        # nests B then A directly -> cycle through the call edge.
        res = run_rule(tmp_path, "lock-order", {"calls.py": """
            import threading

            class Nested:
                def __init__(self):
                    self._alock = threading.Lock()
                    self._block = threading.Lock()

                def inner(self):
                    with self._block:
                        pass

                def outer(self):
                    with self._alock:
                        self.inner()

                def other(self):
                    with self._block:
                        with self._alock:
                            pass
        """})
        assert len(res.violations) == 1

    def test_same_attr_name_across_classes_not_unified(self, tmp_path):
        # A._lock -> A._aux in one class; B._aux -> B._lock in another.
        # Unifying by attribute name would fabricate a cycle.
        res = run_rule(tmp_path, "lock-order", {"two.py": """
            import threading

            class A:
                def __init__(self):
                    self._lock = threading.Lock()
                    self._aux = threading.Lock()

                def m(self):
                    with self._lock:
                        with self._aux:
                            pass

            class B:
                def __init__(self):
                    self._lock = threading.Lock()
                    self._aux = threading.Lock()

                def m(self):
                    with self._aux:
                        with self._lock:
                            pass
        """})
        assert res.ok


# ---------------------------------------------------------------------------
# pickle-boundary
# ---------------------------------------------------------------------------


class TestPickleBoundary:
    def test_spec_with_naked_lock_flagged(self, tmp_path):
        res = run_rule(tmp_path, "pickle-boundary", {"ship.py": """
            import threading

            class ShipSpec:
                def __init__(self):
                    self.size = 1
                    self._lock = threading.Lock()
        """})
        assert len(res.violations) == 1
        assert res.violations[0].symbol == "ShipSpec._lock"

    def test_getstate_pop_idiom_passes(self, tmp_path):
        res = run_rule(tmp_path, "pickle-boundary", {"ship.py": """
            import threading

            class ShipSpec:
                def __init__(self):
                    self._lock = threading.Lock()

                def __getstate__(self):
                    state = dict(self.__dict__)
                    state.pop("_lock")
                    return state

                def __setstate__(self, state):
                    self.__dict__.update(state)
                    self._lock = threading.Lock()
        """})
        assert res.ok

    def test_base_class_getstate_covers_subclass(self, tmp_path):
        res = run_rule(tmp_path, "pickle-boundary", {"ship.py": """
            import threading

            class BaseSpec:
                def __getstate__(self):
                    state = dict(self.__dict__)
                    state.pop("_lock", None)
                    return state

            class ShipSpec(BaseSpec):
                def __init__(self):
                    self._lock = threading.Lock()
        """})
        assert res.ok

    def test_non_boundary_class_ignored(self, tmp_path):
        res = run_rule(tmp_path, "pickle-boundary", {"local.py": """
            import threading

            class Aggregator:
                def __init__(self):
                    self._lock = threading.Lock()
        """})
        assert res.ok


# ---------------------------------------------------------------------------
# event-kind
# ---------------------------------------------------------------------------


class TestEventKinds:
    def test_missing_registry_flagged_once(self, tmp_path):
        res = run_rule(tmp_path, "event-kind", {"emit.py": """
            from events import Event

            def go(log):
                log.emit(Event(t=0.0, kind="task", stage="queued"))
                log.emit(Event(t=0.0, kind="gauge", stage="x"))
        """})
        assert len(res.violations) == 1
        assert res.violations[0].symbol == "EVENT_KINDS"

    def test_undeclared_emission_flagged(self, tmp_path):
        res = run_rule(tmp_path, "event-kind", {
            "events.py": 'EVENT_KINDS: tuple = ("task",)\n',
            "emit.py": """
                from events import Event

                def go(log):
                    log.emit(Event(t=0.0, kind="mystery", stage="x"))
            """,
        })
        assert [v.symbol for v in res.violations] == ["emit:mystery"]

    def test_consumer_of_never_emitted_kind_flagged(self, tmp_path):
        res = run_rule(tmp_path, "event-kind", {
            "events.py": 'EVENT_KINDS = ("task", "ghost")\n',
            "emit.py": """
                from events import Event

                def go(log):
                    log.emit(Event(t=0.0, kind="task", stage="x"))
            """,
            "metrics.py": """
                def consume(ev):
                    if ev.kind == "ghost":
                        return 1
            """,
        })
        assert [v.symbol for v in res.violations] == ["consume:ghost"]

    def test_declared_and_consumed_passes(self, tmp_path):
        res = run_rule(tmp_path, "event-kind", {
            "events.py": 'EVENT_KINDS = ("task",)\n',
            "emit.py": """
                from events import Event

                def go(log):
                    log.emit(Event(t=0.0, kind="task", stage="x"))
            """,
            "metrics.py": """
                def consume(ev):
                    if ev.kind == "task":
                        return 1
            """,
        })
        assert res.ok

    def test_helper_emission_counts(self, tmp_path):
        # A kind emitted only through an EventLog helper method still
        # counts as emitted for the consumer check.
        res = run_rule(tmp_path, "event-kind", {
            "events.py": """
                EVENT_KINDS = ("gauge",)

                class Event:
                    pass

                class EventLog:
                    def gauge(self, name, value):
                        return Event(kind="gauge")
            """,
            "metrics.py": """
                def consume(ev):
                    if ev.kind == "gauge":
                        return 1
            """,
        })
        assert res.ok


# ---------------------------------------------------------------------------
# spec-roundtrip
# ---------------------------------------------------------------------------


class TestSpecRoundtrip:
    def test_dropped_field_flagged(self, tmp_path):
        res = run_rule(tmp_path, "spec-roundtrip", {
            "myspec.py": """
                from dataclasses import dataclass

                @dataclass
                class FooSpec:
                    alpha: int = 0
                    beta: int = 0
            """,
            "specfile.py": """
                from myspec import FooSpec

                def spec_to_dict(spec):
                    return {"alpha": spec.alpha}

                def spec_from_dict(d):
                    return FooSpec(alpha=d.get("alpha", 0))
            """,
        })
        assert [v.symbol for v in res.violations] == ["FooSpec.beta"]

    def test_all_fields_handled_passes(self, tmp_path):
        res = run_rule(tmp_path, "spec-roundtrip", {
            "myspec.py": """
                from dataclasses import dataclass

                @dataclass
                class FooSpec:
                    alpha: int = 0
                    beta: int = 0
            """,
            "specfile.py": """
                from myspec import FooSpec

                def spec_to_dict(spec):
                    return {"alpha": spec.alpha, "beta": spec.beta}

                def spec_from_dict(d):
                    return FooSpec(alpha=d.get("alpha", 0), beta=d.get("beta", 0))
            """,
        })
        assert res.ok

    def test_own_to_dict_counts_as_handled(self, tmp_path):
        # The PoolSpec pattern: specfile delegates to the class's own
        # to_dict/from_dict, which mention the field.
        res = run_rule(tmp_path, "spec-roundtrip", {
            "myspec.py": """
                from dataclasses import dataclass

                @dataclass
                class FooSpec:
                    alpha: int = 0
                    beta: int = 0

                    def to_dict(self):
                        return {"alpha": self.alpha, "beta": self.beta}
            """,
            "specfile.py": """
                from myspec import FooSpec

                def spec_to_dict(spec):
                    return FooSpec.to_dict(spec)

                def spec_from_dict(d):
                    return FooSpec(alpha=d.get("alpha", 0))
            """,
        })
        assert res.ok

    def test_unaudited_dataclass_ignored(self, tmp_path):
        # Dataclasses specfile never touches are out of scope.
        res = run_rule(tmp_path, "spec-roundtrip", {
            "other.py": """
                from dataclasses import dataclass

                @dataclass
                class Unrelated:
                    hidden: int = 0
            """,
            "specfile.py": """
                def spec_to_dict(spec):
                    return {}

                def spec_from_dict(d):
                    return None
            """,
        })
        assert res.ok


# ---------------------------------------------------------------------------
# thread-lifecycle
# ---------------------------------------------------------------------------


class TestThreadLifecycle:
    def test_fire_and_forget_flagged(self, tmp_path):
        res = run_rule(tmp_path, "thread-lifecycle", {"fire.py": """
            import threading

            def start(fn):
                t = threading.Thread(target=fn)
                t.start()
        """})
        assert [v.rule for v in res.violations] == ["thread-lifecycle"]

    def test_daemon_true_passes(self, tmp_path):
        res = run_rule(tmp_path, "thread-lifecycle", {"fire.py": """
            import threading

            def start(fn):
                t = threading.Thread(target=fn, daemon=True)
                t.start()
        """})
        assert res.ok

    def test_join_in_owning_class_passes(self, tmp_path):
        res = run_rule(tmp_path, "thread-lifecycle", {"runner.py": """
            import threading

            class Runner:
                def start(self, fn):
                    self._t = threading.Thread(target=fn)
                    self._t.start()

                def stop(self):
                    self._t.join()
        """})
        assert res.ok

    def test_str_join_does_not_count(self, tmp_path):
        res = run_rule(tmp_path, "thread-lifecycle", {"fire.py": """
            import threading

            def start(fn):
                t = threading.Thread(target=fn)
                t.start()
                return ", ".join(["a", "b"])
        """})
        assert len(res.violations) == 1


# ---------------------------------------------------------------------------
# Baseline + CLI
# ---------------------------------------------------------------------------


class TestBaselineAndCLI:
    def test_baseline_round_trip_kills_known_findings(self, tmp_path):
        src = tmp_path / "spin.py"
        src.write_text(textwrap.dedent("""
            import time

            def drain(state):
                while not state.done:
                    time.sleep(0.05)
        """))
        res = analyze_paths([str(tmp_path)], rules=["busy-wait"])
        assert len(res.violations) == 1

        base = tmp_path / "base.json"
        write_baseline(str(base), res.violations,
                       reasons={res.violations[0].fingerprint: "known debt"})
        assert load_baseline(str(base))[res.violations[0].fingerprint] == "known debt"

        res2 = analyze_paths([str(tmp_path)], baseline=str(base), rules=["busy-wait"])
        assert res2.ok
        assert len(res2.baselined) == 1
        assert not res2.stale_baseline

    def test_baseline_fingerprint_survives_line_churn(self, tmp_path):
        src = tmp_path / "spin.py"
        body = """
            import time

            def drain(state):
                while not state.done:
                    time.sleep(0.05)
        """
        src.write_text(textwrap.dedent(body))
        res = analyze_paths([str(tmp_path)], rules=["busy-wait"])
        base = tmp_path / "base.json"
        write_baseline(str(base), res.violations)
        # unrelated edit shifts every line number; the fingerprint holds
        src.write_text("# a new comment\n\n\n" + textwrap.dedent(body))
        res2 = analyze_paths([str(tmp_path)], baseline=str(base), rules=["busy-wait"])
        assert res2.ok and len(res2.baselined) == 1

    def test_stale_baseline_entries_reported(self, tmp_path):
        (tmp_path / "ok.py").write_text("x = 1\n")
        base = tmp_path / "base.json"
        base.write_text(json.dumps({"version": 1, "entries": [
            {"fingerprint": "busy-wait:gone.py:drain", "rule": "busy-wait",
             "path": "gone.py", "reason": "was fixed"},
        ]}))
        res = analyze_paths([str(tmp_path)], baseline=str(base))
        assert res.ok
        assert res.stale_baseline == ["busy-wait:gone.py:drain"]

    def test_cli_exit_codes(self, tmp_path, capsys):
        bad = tmp_path / "spin.py"
        bad.write_text("import time\n\ndef f(s):\n    while not s.done:\n        time.sleep(0.05)\n")
        assert analyze_main([str(tmp_path), "--fail-on-violation"]) == 1
        assert analyze_main([str(tmp_path)]) == 0  # report-only mode
        out = capsys.readouterr()
        assert "[busy-wait]" in out.out
        bad.write_text("x = 1\n")
        assert analyze_main([str(tmp_path), "--fail-on-violation"]) == 0
        assert analyze_main([str(tmp_path / "missing"), ]) == 2
        assert analyze_main([str(tmp_path), "--rule", "no-such-rule"]) == 2

    def test_cli_write_baseline(self, tmp_path, capsys):
        (tmp_path / "spin.py").write_text(
            "import time\n\ndef f(s):\n    while not s.done:\n        time.sleep(0.05)\n")
        base = tmp_path / "base.json"
        assert analyze_main([str(tmp_path), "--write-baseline", str(base)]) == 0
        capsys.readouterr()
        assert analyze_main([str(tmp_path), "--fail-on-violation",
                             "--baseline", str(base)]) == 0

    def test_every_rule_has_a_checker(self):
        assert set(all_checkers()) == {
            "busy-wait", "lock-order", "pickle-boundary",
            "event-kind", "spec-roundtrip", "thread-lifecycle",
        }


# ---------------------------------------------------------------------------
# The gate: src/repro itself must analyze clean against the baseline
# ---------------------------------------------------------------------------


class TestGate:
    def test_src_repro_clean_against_committed_baseline(self, monkeypatch):
        monkeypatch.chdir(REPO_ROOT)
        res = analyze_paths(["src/repro"], baseline="analyze-baseline.json")
        assert res.ok, "\n".join(v.render() for v in res.violations)
        assert not res.stale_baseline, res.stale_baseline

    def test_baseline_entries_have_reasons(self):
        doc = json.load(open(os.path.join(REPO_ROOT, "analyze-baseline.json")))
        for e in doc["entries"]:
            assert e.get("reason", "").strip(), f"baseline entry without a reason: {e}"
            assert "TODO" not in e["reason"]


# ---------------------------------------------------------------------------
# Runtime lock sanitizer
# ---------------------------------------------------------------------------


class TestRuntimeSanitizer:
    def _pair(self, g):
        a = runtime.TracedLock(threading.Lock(), "a.py:1", g)
        b = runtime.TracedRLock(threading.RLock(), "b.py:2", g)
        return a, b

    def test_inversion_detected(self):
        g = runtime.LockGraph()
        a, b = self._pair(g)

        def ab():
            with a:
                with b:
                    pass

        def ba():
            with b:
                with a:
                    pass

        for fn in (ab, ba):
            t = threading.Thread(target=fn)
            t.start()
            t.join()
        assert g.find_cycles() == [["a.py:1", "b.py:2"]]
        report = g.report_cycles()
        assert "a.py:1 -> b.py:2" in report and "b.py:2 -> a.py:1" in report
        with pytest.raises(AssertionError, match="inversion"):
            g.assert_acyclic()

    def test_consistent_order_is_clean(self):
        g = runtime.LockGraph()
        a, b = self._pair(g)
        for _ in range(3):
            with a:
                with b:
                    pass
        assert g.edges and g.find_cycles() == []
        g.assert_acyclic()

    def test_rlock_reentry_is_not_an_edge(self):
        g = runtime.LockGraph()
        r = runtime.TracedRLock(threading.RLock(), "r.py:1", g)
        with r:
            with r:
                pass
        assert not g.edges

    def test_condition_protocol_over_traced_rlock(self):
        g = runtime.LockGraph()
        inner = runtime.TracedRLock(threading.RLock(), "c.py:1", g)
        cond = threading.Condition(inner)
        hits = []

        def waiter():
            with cond:
                cond.wait(timeout=5)
                hits.append(1)

        t = threading.Thread(target=waiter)
        t.start()
        for _ in range(100):  # until the waiter holds the condition
            time.sleep(0.01)
            if g.acquisitions:
                break
        with cond:
            cond.notify_all()
        t.join(timeout=5)
        assert hits == [1]
        # wait() released the lock: the held stack is balanced, no self-edges
        assert all(a != b for a, b in g.edges)

    def test_install_filters_by_caller_package(self, tmp_path):
        if runtime.installed():
            pytest.skip("sanitizer session already active (REPRO_LOCK_SANITIZER=1)")
        g = runtime.LockGraph()
        runtime.install(g)
        try:
            # this test file is not under src/repro -> raw lock, untraced
            raw = threading.Lock()
            assert not isinstance(raw, runtime._TracedLockBase)
            # a lock created by repro code IS traced
            from repro.observe.events import EventLog
            log = EventLog(capacity=4)
            assert isinstance(log._lock, runtime.TracedLock)
            log.gauge("x", 1.0)
            assert g.acquisitions > 0 and g.find_cycles() == []
        finally:
            runtime.uninstall()
        assert threading.Lock().__class__.__name__ == "lock"

    def test_install_from_env_off(self, monkeypatch):
        if runtime.installed():
            pytest.skip("sanitizer session already active")
        monkeypatch.delenv(runtime.ENV_FLAG, raising=False)
        assert runtime.install_from_env() is False
        assert not runtime.installed()

    def test_sanitized_subprocess_end_to_end(self):
        code = textwrap.dedent("""
            from repro.analyze import runtime
            assert runtime.install_from_env(), "env flag should install"
            from repro.observe.events import EventLog
            log = EventLog(capacity=8)
            for i in range(4):
                log.gauge("x", float(i))
            assert type(log._lock).__name__ == "TracedLock", type(log._lock)
            g = runtime.graph()
            assert g.acquisitions >= 4
            g.assert_acyclic()
            print("SANITIZER_OK")
        """)
        env = dict(os.environ,
                   REPRO_LOCK_SANITIZER="1",
                   PYTHONPATH=os.path.join(REPO_ROOT, "src"))
        proc = subprocess.run([sys.executable, "-c", code], env=env,
                              capture_output=True, text=True, timeout=60)
        assert proc.returncode == 0, proc.stderr
        assert "SANITIZER_OK" in proc.stdout
