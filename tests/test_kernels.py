"""Per-kernel validation: Pallas (interpret=True) and XLA paths vs. the
pure-jnp oracle, swept over shapes/dtypes with hypothesis."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
pytest.importorskip("hypothesis")  # optional dep: pip install -e .[test]
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.wkv6.ops import wkv6
from repro.kernels.rmsnorm.ops import rmsnorm

SETTINGS = dict(
    max_examples=8, deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)


def rel_err(a, b):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return float(np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-9))


@st.composite
def attn_shapes(draw):
    b = draw(st.sampled_from([1, 2]))
    kvh = draw(st.sampled_from([1, 2]))
    group = draw(st.sampled_from([1, 2, 4]))
    s = draw(st.sampled_from([32, 64, 96]))
    d = draw(st.sampled_from([16, 32]))
    dtype = draw(st.sampled_from([jnp.float32, jnp.bfloat16]))
    return b, kvh * group, kvh, s, d, dtype


class TestFlashAttention:
    @given(attn_shapes(), st.booleans(), st.sampled_from([None, 24]))
    @settings(**SETTINGS)
    def test_xla_matches_ref(self, shp, causal, window, ):
        b, h, kvh, s, d, dtype = shp
        key = jax.random.PRNGKey(b * 1000 + h)
        q = jax.random.normal(key, (b, h, s, d), dtype)
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, kvh, s, d), dtype)
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, kvh, s, d), dtype)
        if window is not None and not causal:
            causal = True   # windows only used with causal attention here
        ref = flash_attention(q, k, v, causal=causal, window=window, impl="ref")
        out = flash_attention(q, k, v, causal=causal, window=window, impl="xla", block_k=32)
        tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
        assert rel_err(out, ref) < tol

    @given(attn_shapes())
    @settings(**SETTINGS)
    def test_pallas_interpret_matches_ref(self, shp):
        b, h, kvh, s, d, dtype = shp
        key = jax.random.PRNGKey(h * 100 + s)
        q = jax.random.normal(key, (b, h, s, d), dtype)
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, kvh, s, d), dtype)
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, kvh, s, d), dtype)
        ref = flash_attention(q, k, v, causal=True, impl="ref")
        out = flash_attention(q, k, v, causal=True, impl="interpret",
                              block_q=32, block_k=32)
        tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
        assert rel_err(out, ref) < tol

    def test_blockwise_skip_equals_full(self):
        key = jax.random.PRNGKey(0)
        q = jax.random.normal(key, (2, 4, 128, 32))
        k = jax.random.normal(jax.random.fold_in(key, 1), (2, 2, 128, 32))
        v = jax.random.normal(jax.random.fold_in(key, 2), (2, 2, 128, 32))
        ref = flash_attention(q, k, v, causal=True, impl="ref")
        out = flash_attention(q, k, v, causal=True, impl="xla", block_k=32,
                              skip_masked_blocks=True)
        assert rel_err(out, ref) < 1e-4

    def test_q_offset_decode_chunk(self):
        """Chunked prefill: q at an offset into the kv sequence."""
        key = jax.random.PRNGKey(3)
        skv, sq, off = 64, 16, 48
        q = jax.random.normal(key, (1, 2, sq, 16))
        k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, skv, 16))
        v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, skv, 16))
        ref = flash_attention(q, k, v, causal=True, q_offset=off, impl="ref")
        out = flash_attention(q, k, v, causal=True, q_offset=off, impl="xla", block_k=16)
        assert rel_err(out, ref) < 1e-4


class TestDecodeAttention:
    @given(st.sampled_from([1, 2, 4]), st.sampled_from([1, 2]),
           st.sampled_from([32, 64]), st.sampled_from([jnp.float32, jnp.bfloat16]))
    @settings(**SETTINGS)
    def test_interpret_matches_ref(self, group, kvh, s, dtype):
        b, d = 2, 16
        h = group * kvh
        key = jax.random.PRNGKey(group * 10 + s)
        q = jax.random.normal(key, (b, h, d), dtype)
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, kvh, s, d), dtype)
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, kvh, s, d), dtype)
        lengths = jnp.asarray([s // 2, s - 1], jnp.int32)
        ref = decode_attention(q, k, v, lengths, impl="ref")
        out = decode_attention(q, k, v, lengths, impl="interpret", block_k=16)
        tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
        assert rel_err(out, ref) < tol

    def test_windowed(self):
        key = jax.random.PRNGKey(1)
        q = jax.random.normal(key, (2, 2, 16))
        k = jax.random.normal(jax.random.fold_in(key, 1), (2, 2, 64, 16))
        v = jax.random.normal(jax.random.fold_in(key, 2), (2, 2, 64, 16))
        lengths = jnp.asarray([40, 63], jnp.int32)
        ref = decode_attention(q, k, v, lengths, window=16, impl="ref")
        out = decode_attention(q, k, v, lengths, window=16, impl="interpret", block_k=16)
        assert rel_err(out, ref) < 1e-4


class TestRglruScan:
    @given(st.sampled_from([1, 3]), st.sampled_from([16, 64, 96]),
           st.sampled_from([8, 32]), st.sampled_from([jnp.float32, jnp.bfloat16]))
    @settings(**SETTINGS)
    def test_impls_match_ref(self, b, s, d, dtype):
        key = jax.random.PRNGKey(s + d)
        log_a = -jax.random.uniform(key, (b, s, d), jnp.float32, 0.01, 3.0).astype(dtype)
        x = jax.random.normal(jax.random.fold_in(key, 1), (b, s, d), dtype)
        h0 = jax.random.normal(jax.random.fold_in(key, 2), (b, d), dtype)
        hr, hfr = rglru_scan(log_a, x, h0, impl="ref")
        tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
        hx, hfx = rglru_scan(log_a, x, h0, impl="xla")
        assert rel_err(hx, hr) < tol and rel_err(hfx, hfr) < tol
        hp, hfp = rglru_scan(log_a, x, h0, impl="interpret")
        assert rel_err(hp, hr) < tol and rel_err(hfp, hfr) < tol

    def test_strong_decay_stable(self):
        """No overflow/NaN with extreme decay values."""
        b, s, d = 1, 64, 16
        log_a = jnp.full((b, s, d), -30.0)
        x = jnp.ones((b, s, d))
        h0 = jnp.ones((b, d)) * 100
        for impl in ("ref", "xla", "interpret"):
            hs, hf = rglru_scan(log_a, x, h0, impl=impl)
            assert np.isfinite(np.asarray(hs)).all()


class TestWkv6:
    @given(st.sampled_from([1, 2]), st.sampled_from([2, 4]),
           st.sampled_from([16, 48, 64]), st.sampled_from([8, 16]))
    @settings(**SETTINGS)
    def test_impls_match_ref(self, b, h, s, k_dim):
        key = jax.random.PRNGKey(s * 7 + h)
        mk = lambda i, shape, scale=0.5: jax.random.normal(jax.random.fold_in(key, i), shape) * scale
        r = mk(0, (b, h, s, k_dim))
        k = mk(1, (b, h, s, k_dim))
        v = mk(2, (b, h, s, k_dim))
        lw = -jax.random.uniform(jax.random.fold_in(key, 3), (b, h, s, k_dim), minval=0.01, maxval=4.0)
        u = mk(4, (h, k_dim), 0.3)
        s0 = mk(5, (b, h, k_dim, k_dim), 0.1)
        o_ref, s_ref = wkv6(r, k, v, lw, u, s0, impl="ref")
        o_x, s_x = wkv6(r, k, v, lw, u, s0, impl="xla", chunk=16)
        assert rel_err(o_x, o_ref) < 1e-3 and rel_err(s_x, s_ref) < 1e-3
        o_p, s_p = wkv6(r, k, v, lw, u, s0, impl="interpret", chunk=16)
        assert rel_err(o_p, o_ref) < 1e-3 and rel_err(s_p, s_ref) < 1e-3

    def test_extreme_decay_no_overflow(self):
        """The chunked form must not overflow even with huge decay."""
        b, h, s, kd = 1, 1, 32, 8
        key = jax.random.PRNGKey(0)
        r = jax.random.normal(key, (b, h, s, kd))
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, h, s, kd))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, h, s, kd))
        lw = jnp.full((b, h, s, kd), -50.0)   # exp(+50cum) would overflow naive factoring
        u = jnp.zeros((h, kd))
        s0 = jnp.zeros((b, h, kd, kd))
        for impl in ("xla", "interpret"):
            o, sf = wkv6(r, k, v, lw, u, s0, impl=impl, chunk=16)
            assert np.isfinite(np.asarray(o)).all()
            assert np.isfinite(np.asarray(sf)).all()

    def test_statefulness_chunk_boundary(self):
        """Splitting a sequence across two calls == one call (state carry)."""
        b, h, s, kd = 1, 2, 32, 8
        key = jax.random.PRNGKey(9)
        mk = lambda i, shape: jax.random.normal(jax.random.fold_in(key, i), shape) * 0.5
        r, k, v = mk(0, (b, h, s, kd)), mk(1, (b, h, s, kd)), mk(2, (b, h, s, kd))
        lw = -jax.random.uniform(jax.random.fold_in(key, 3), (b, h, s, kd), minval=0.1, maxval=2.0)
        u = mk(4, (h, kd))
        s0 = jnp.zeros((b, h, kd, kd))
        o_full, s_full = wkv6(r, k, v, lw, u, s0, impl="xla", chunk=8)
        o1, s1 = wkv6(r[:, :, :16], k[:, :, :16], v[:, :, :16], lw[:, :, :16], u, s0, impl="xla", chunk=8)
        o2, s2 = wkv6(r[:, :, 16:], k[:, :, 16:], v[:, :, 16:], lw[:, :, 16:], u, s1, impl="xla", chunk=8)
        assert rel_err(np.concatenate([o1, o2], axis=2), o_full) < 1e-4
        assert rel_err(s2, s_full) < 1e-4


class TestOddLengthParity:
    """Pallas kernels vs refs on odd (non-multiple-of-block) sequence
    lengths: the padding/masking path must be exact in both dtypes."""

    @given(st.sampled_from([33, 40, 72, 100]),
           st.sampled_from([jnp.float32, jnp.bfloat16]),
           st.booleans())
    @settings(**SETTINGS)
    def test_flash_attention_odd_seq(self, s, dtype, causal):
        key = jax.random.PRNGKey(s)
        q = jax.random.normal(key, (1, 2, s, 16), dtype)
        k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, s, 16), dtype)
        v = jax.random.normal(jax.random.fold_in(key, 2), (1, 1, s, 16), dtype)
        ref = flash_attention(q, k, v, causal=causal, impl="ref")
        out = flash_attention(q, k, v, causal=causal, impl="interpret",
                              block_q=32, block_k=32)
        tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
        assert rel_err(out, ref) < tol

    def test_flash_attention_odd_seq_with_window_and_offset(self):
        key = jax.random.PRNGKey(7)
        q = jax.random.normal(key, (1, 2, 17, 16))
        k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 50, 16))
        v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 50, 16))
        for kwargs in ({"q_offset": 33}, {"window": 24, "q_offset": 33}):
            ref = flash_attention(q, k, v, causal=True, impl="ref", **kwargs)
            out = flash_attention(q, k, v, causal=True, impl="interpret",
                                  block_q=16, block_k=16, **kwargs)
            assert rel_err(out, ref) < 1e-4

    def test_flash_attention_odd_kv_only(self):
        """kv padding must not leak into the softmax when sq != skv."""
        key = jax.random.PRNGKey(11)
        q = jax.random.normal(key, (2, 2, 32, 16))
        k = jax.random.normal(jax.random.fold_in(key, 1), (2, 2, 45, 16))
        v = jax.random.normal(jax.random.fold_in(key, 2), (2, 2, 45, 16))
        ref = flash_attention(q, k, v, causal=False, impl="ref")
        out = flash_attention(q, k, v, causal=False, impl="interpret",
                              block_q=16, block_k=16)
        assert rel_err(out, ref) < 1e-4

    @given(st.sampled_from([(3, 5, 48), (7, 40), (13, 33)]),
           st.sampled_from([jnp.float32, jnp.bfloat16]))
    @settings(**SETTINGS)
    def test_rmsnorm_odd_rows(self, shape, dtype):
        from repro.kernels.rmsnorm.kernel import rmsnorm_pallas

        key = jax.random.PRNGKey(shape[-1])
        x = jax.random.normal(key, shape, dtype)
        w = jax.random.normal(jax.random.fold_in(key, 1), (shape[-1],), dtype) * 0.1
        ref = rmsnorm(x, w, impl="ref")
        # block_rows=4 forces row padding for every odd row count here
        out = rmsnorm_pallas(x, w, block_rows=4, interpret=True)
        tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
        assert rel_err(out, ref) < tol


class TestRmsnorm:
    @given(st.sampled_from([(4, 32), (2, 3, 64), (1, 128)]),
           st.sampled_from([jnp.float32, jnp.bfloat16]),
           st.sampled_from([0.0, 1.0]))
    @settings(**SETTINGS)
    def test_interpret_matches_ref(self, shape, dtype, offset):
        key = jax.random.PRNGKey(shape[-1])
        x = jax.random.normal(key, shape, dtype)
        w = jax.random.normal(jax.random.fold_in(key, 1), (shape[-1],), dtype) * 0.1
        ref = rmsnorm(x, w, scale_offset=offset, impl="ref")
        out = rmsnorm(x, w, scale_offset=offset, impl="interpret")
        tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
        assert rel_err(out, ref) < tol

    def test_unit_variance_property(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (128, 64)) * 7 + 3
        y = rmsnorm(x, jnp.ones((64,)), impl="ref")
        ms = np.mean(np.asarray(y) ** 2, axis=-1)
        assert np.allclose(ms, np.asarray((x / np.sqrt((np.asarray(x)**2).mean(-1, keepdims=True)))**2).mean(-1), atol=1e-3)
