"""Per-architecture smoke tests (assignment requirement): a REDUCED config
of each family runs one forward + one train step on CPU, asserting output
shapes and absence of NaNs; plus decode-vs-forward equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.configs.base import SHAPES, shape_applicable
from repro.models import build_model
from repro.models import transformer as tmod
from repro.train import OptimizerConfig, make_train_step, init_train_state


def _batch_for(cfg, B=2, S=16, seed=0):
    key = jax.random.PRNGKey(seed)
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "whisper":
        batch["frames"] = jax.random.normal(jax.random.fold_in(key, 2),
                                            (B, cfg.encoder_seq, cfg.d_model)) * 0.1
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(jax.random.fold_in(key, 2),
                                             (B, cfg.vision_patches, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_shapes_no_nan(self, arch):
        cfg = smoke_config(arch).with_(dtype="float32")
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        B, S = 2, 16
        batch = _batch_for(cfg, B, S)
        logits, _ = m.forward(params, batch)
        assert logits.shape == (B, S, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all()

    def test_train_step_decreases_loss_no_nan(self, arch):
        cfg = smoke_config(arch).with_(dtype="float32", grad_accum=2)
        m = build_model(cfg)
        oc = OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=50)
        params, opt = init_train_state(m, oc, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(m, oc))
        batch = _batch_for(cfg, 4, 16)
        losses = []
        for _ in range(3):
            params, opt, metrics = step(params, opt, batch)
            losses.append(float(metrics["loss"]))
        assert all(np.isfinite(l) for l in losses)
        assert losses[-1] < losses[0]   # same batch -> must improve

    def test_decode_step_shapes(self, arch):
        cfg = smoke_config(arch).with_(dtype="float32")
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        B = 2
        cache = m.init_cache(B, 32)
        if cfg.family == "whisper":
            from repro.models import whisper as wmod
            frames = jax.random.normal(jax.random.PRNGKey(1), (B, cfg.encoder_seq, cfg.d_model)) * 0.1
            cache = wmod.prefill_cross(cfg, params, cache, frames)
        logits, cache2 = m.decode_step(
            params, cache, jnp.zeros((B, 1), jnp.int32), jnp.zeros((B,), jnp.int32)
        )
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all()

    def test_long_context_applicability(self, arch):
        cfg = get_config(arch)
        ok, reason = shape_applicable(cfg, SHAPES["long_500k"])
        assert ok == cfg.is_subquadratic
        if not ok:
            assert "full-attention" in reason


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS if a != "internvl2-1b"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce the training forward pass.

    MoE runs with a high capacity factor: batched forward can DROP tokens
    at capacity while per-token decode never does — expected Switch-style
    behavior, not a cache bug (covered by test_moe_capacity_drops)."""
    cfg = smoke_config(arch).with_(dtype="float32", capacity_factor=64.0)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    B, S = 2, 10
    batch = _batch_for(cfg, B, S, seed=7)
    tokens = batch["tokens"]
    full_logits, _ = m.forward(params, batch)

    cache = m.init_cache(B, S + 2)
    if cfg.family == "whisper":
        from repro.models import whisper as wmod
        cache = wmod.prefill_cross(cfg, params, cache, batch["frames"])
    dec = []
    for t in range(S):
        logits, cache = m.decode_step(params, cache, tokens[:, t:t + 1],
                                      jnp.full((B,), t, jnp.int32))
        dec.append(logits[:, 0])
    dec = jnp.stack(dec, axis=1)
    err = float(jnp.max(jnp.abs(dec - full_logits)) / (jnp.max(jnp.abs(full_logits)) + 1e-9))
    assert err < 2e-3, f"{arch}: decode diverges from forward ({err:.2e})"


def test_vlm_prefill_then_decode_matches_forward():
    cfg = smoke_config("internvl2-1b").with_(dtype="float32")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    B, S = 2, 10
    batch = _batch_for(cfg, B, S, seed=7)
    full_logits, _ = m.forward(params, batch)
    cache = m.init_cache(B, cfg.vision_patches + S + 2)
    logits0, cache, lengths = tmod.prefill(
        cfg, params, cache, {"tokens": batch["tokens"][:, :1], "patches": batch["patches"]}
    )
    dec = [logits0[:, 0]]
    for t in range(1, S):
        logits, cache = m.decode_step(params, cache, batch["tokens"][:, t:t + 1], lengths)
        lengths = lengths + 1
        dec.append(logits[:, 0])
    dec = jnp.stack(dec, axis=1)
    err = float(jnp.max(jnp.abs(dec - full_logits)) / (jnp.max(jnp.abs(full_logits)) + 1e-9))
    assert err < 2e-3


def test_moe_dispatch_implementations_agree():
    """scatter (memory-light) and onehot (reference) MoE dispatch match."""
    cfg = smoke_config("qwen3-moe-30b-a3b").with_(dtype="float32")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg, 2, 16)
    logits_scatter, _ = m.forward(params, batch)
    cfg2 = cfg.with_(moe_dispatch="onehot")
    m2 = build_model(cfg2)
    logits_onehot, _ = m2.forward(params, batch)
    err = float(jnp.max(jnp.abs(logits_scatter - logits_onehot))
                / (jnp.max(jnp.abs(logits_onehot)) + 1e-9))
    assert err < 1e-5


def test_param_counts_match_published_sizes():
    expected = {
        "qwen3-moe-30b-a3b": 30.5e9,
        "phi3.5-moe-42b-a6.6b": 41.9e9,
        "gemma-2b": 2.5e9,
        "llama3-405b": 405.8e9,
        "yi-6b": 6.1e9,
        "phi4-mini-3.8b": 3.8e9,
        "internvl2-1b": 0.49e9,
        "recurrentgemma-2b": 2.6e9,
        "whisper-large-v3": 1.6e9,
    }
    for arch, want in expected.items():
        got = get_config(arch).n_params
        assert abs(got - want) / want < 0.06, f"{arch}: {got/1e9:.2f}B vs {want/1e9:.2f}B"


def test_moe_capacity_drops_are_forward_only():
    """At tight capacity the batched forward may drop tokens (zero expert
    output for the overflow), while single-token decode never drops —
    documents the known, intended divergence."""
    import numpy as np
    cfg = smoke_config("qwen3-moe-30b-a3b").with_(dtype="float32", capacity_factor=0.25)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(3))
    batch = _batch_for(cfg, 2, 16, seed=11)
    tight, _ = m.forward(params, batch)
    cfg2 = cfg.with_(capacity_factor=64.0)
    loose, _ = build_model(cfg2).forward(params, batch)
    assert not np.allclose(np.asarray(tight), np.asarray(loose), atol=1e-5)
