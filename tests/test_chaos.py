"""Chaos tier: schedules, fault primitives, the work ledger's
exactly-once semantics, the invariant gate, and a mini end-to-end soak
(spawned site SIGKILL + checkpoint corruption included)."""

import os
import threading
import time

import pytest

from repro.chaos import (
    ChaosAction,
    ChaosLink,
    ChaosLocalQueues,
    ChaosRunner,
    ChaosSchedule,
    InvariantChecker,
    RecoveryProbe,
    SoakConfig,
    SoakHarness,
    WorkLedger,
    corrupt_file,
    expected_value,
    truncate_file,
)
from repro.core import FailureInjector, LocalColmenaQueues, Result, TaskServer
from repro.core.executors import WorkerDied


class TestSchedule:
    def test_action_trigger_validation(self):
        with pytest.raises(ValueError):
            ChaosAction(kind="kill_site")                    # no trigger
        with pytest.raises(ValueError):
            ChaosAction(kind="kill_site", at_s=1.0, at_frac=0.5)  # both
        with pytest.raises(ValueError):
            ChaosAction(kind="kill_site", at_frac=1.5)

    def test_due(self):
        a = ChaosAction(kind="x", at_s=2.0)
        assert not a.due(1.9, 1.0) and a.due(2.0, 0.0)
        b = ChaosAction(kind="x", at_frac=0.5)
        assert not b.due(100.0, 0.49) and b.due(0.0, 0.5)

    def test_schedule_round_trips_through_dict(self):
        sched = ChaosSchedule([
            ChaosAction(kind="kill_site", at_frac=0.25, params={"site": "proc"}, scope="proc"),
            ChaosAction(kind="drop_requests", at_s=3.0, params={"rate": 0.3}),
        ])
        clone = ChaosSchedule.from_dict(sched.to_dict())
        assert clone.to_dict() == sched.to_dict()
        assert clone.actions[0].scope == "proc"
        assert clone.actions[1].at_s == 3.0

    def test_runner_fires_on_progress_and_time(self):
        fired = []
        sched = ChaosSchedule([
            ChaosAction(kind="a", at_s=0.0),
            ChaosAction(kind="b", at_frac=0.5),
            ChaosAction(kind="c", at_frac=1.1) if False else ChaosAction(kind="c", at_s=999.0),
        ])
        progress = {"p": 0.0}
        runner = ChaosRunner(
            sched,
            handlers={"a": lambda p: fired.append("a"), "b": lambda p: fired.append("b")},
            progress=lambda: progress["p"], poll_s=0.01,
        ).start()
        time.sleep(0.1)
        progress["p"] = 0.6
        time.sleep(0.1)
        runner.stop()
        assert fired == ["a", "b"]
        assert [f.action.kind for f in runner.fired] == ["a", "b"]
        assert all(f.ok for f in runner.fired)
        assert [a.kind for a in runner.unfired] == ["c"]

    def test_runner_marks_failed_handlers(self):
        sched = ChaosSchedule([
            ChaosAction(kind="boom", at_s=0.0),
            ChaosAction(kind="nope", at_s=0.0),
            ChaosAction(kind="soft", at_s=0.0),
        ])

        def boom(params):
            raise RuntimeError("injector broke")

        runner = ChaosRunner(
            sched, handlers={"boom": boom, "soft": lambda p: {"ok": False, "why": "drill failed"}},
            poll_s=0.01,
        ).start()
        time.sleep(0.1)
        runner.stop()
        by_kind = {f.action.kind: f for f in runner.fired}
        assert not by_kind["boom"].ok and "injector broke" in str(by_kind["boom"].detail)
        assert not by_kind["nope"].ok          # no handler registered
        assert not by_kind["soft"].ok          # handler reported ok=False


class TestFaultPrimitives:
    def test_link_drops_requests_only_in_window(self):
        q = ChaosLocalQueues(chaos=ChaosLink(seed=7))
        server = TaskServer(q, {"f": lambda x: x}, n_workers=1).start()
        q.chaos.enable_drop(rate=1.0, duration_s=5.0)
        q.send_inputs(1, method="f")
        assert q.get_result(timeout=0.4) is None     # dropped on the floor
        assert q.chaos.dropped == 1
        q.chaos.disable()
        q.send_inputs(2, method="f")
        r = q.get_result(timeout=5)
        assert r is not None and r.value == 2
        server.stop()                                # kill sentinel never dropped

    def test_link_delays_results(self):
        q = ChaosLocalQueues(chaos=ChaosLink())
        server = TaskServer(q, {"f": lambda x: x}, n_workers=1).start()
        q.send_inputs(3, method="f")
        time.sleep(0.3)                              # let the result land
        q.chaos.enable_delay(delay_s=0.15, duration_s=5.0)
        t0 = time.monotonic()
        r = q.get_result(timeout=5)
        assert r is not None and time.monotonic() - t0 >= 0.15
        assert q.chaos.delayed >= 1
        server.stop()

    def test_truncate_and_corrupt_file(self, tmp_path):
        p = str(tmp_path / "blob.bin")
        with open(p, "wb") as f:
            f.write(bytes(range(256)) * 4)
        before = open(p, "rb").read()
        assert truncate_file(p, keep_fraction=0.5) == 512
        assert os.path.getsize(p) == 512
        n = corrupt_file(p, n_bytes=8, seed=3)
        assert n == 8
        assert open(p, "rb").read() != before[:512]  # bytes really flipped

    def test_injector_storm_dooms_cohort(self):
        inj = FailureInjector(storms=[(0.05, 2)])
        r = Result(method="f", args=(), kwargs={})
        inj.before_task(0, r)                        # activates the clock
        time.sleep(0.08)
        died = 0
        for wid in (1, 2, 3):
            try:
                inj.before_task(wid, r)
            except WorkerDied:
                died += 1
        assert died == 2 and inj.storms_fired == 1

    def test_doom_cohort_runtime(self):
        inj = FailureInjector()
        inj.doom_cohort(1)
        r = Result(method="f", args=(), kwargs={})
        with pytest.raises(WorkerDied):
            inj.before_task(5, r)
        inj.before_task(6, r)                        # only one was doomed

    def test_storm_schedule_survives_pickle(self):
        import pickle

        inj = FailureInjector(storms=[(0.01, 1)], seed=3)
        clone = pickle.loads(pickle.dumps(inj))
        r = Result(method="f", args=(), kwargs={})
        clone.before_task(0, r)                      # re-anchors in this process
        time.sleep(0.03)
        with pytest.raises(WorkerDied):
            clone.before_task(1, r)


def _delivery(index, task_id="tid-0", value=None, success=True):
    r = Result(method="soak", args=(index,), kwargs={}, task_info={"index": index})
    r.task_id = task_id
    if success:
        r.set_success(expected_value(index) if value is None else value)
    else:
        from repro.core import FailureKind

        r.set_failure(FailureKind.WORKER_DIED, "storm")
    return r


class TestWorkLedger:
    def test_exactly_once_accept_then_violation(self):
        led = WorkLedger(4)
        assert led.take(2) == [0, 1]
        led.on_submitted(0, "local", "t0", now=0.0)
        assert led.accept(_delivery(0, "t0")) == "accepted"
        assert led.completed == 1
        # second delivery of a never-resubmitted index = hard violation
        assert led.accept(_delivery(0, "t0")) == "violation"
        assert led.exactly_once_violations == [0]

    def test_resubmitted_duplicate_is_suppressed_not_violated(self):
        led = WorkLedger(4, resubmit_after_s=0.0)
        led.take(1)
        led.on_submitted(0, "proc", "tA", now=0.0)
        assert led.overdue(now=1.0) == 1             # deadline passed -> recycled
        assert led.take(1) == [0] and led.resubmits == 1
        led.on_submitted(0, "local", "tB", now=1.0)
        assert led.accept(_delivery(0, "tB")) == "accepted"
        assert led.accept(_delivery(0, "tA")) == "duplicate"   # other attempt: benign
        assert led.duplicates_suppressed == 1
        assert led.accept(_delivery(0, "tB")) == "violation"   # same attempt twice
        assert led.exactly_once_violations == [0]

    def test_failed_delivery_recycles(self):
        led = WorkLedger(2)
        led.take(1)
        led.on_submitted(0, "proc", "tA", now=0.0)
        assert led.accept(_delivery(0, "tA", success=False)) == "failed"
        assert led.failed_deliveries == 1 and led.completed == 0
        assert led.take(1) == [0]                    # still owed a success

    def test_value_integrity_checked(self):
        led = WorkLedger(2)
        led.take(1)
        led.on_submitted(0, "local", "t0", now=0.0)
        led.accept(_delivery(0, "t0", value=-999))
        assert led.value_errors == [0]

    def test_requeue_site_and_fresh_floor(self):
        led = WorkLedger(10)
        for i in led.take(4):
            led.on_submitted(i, "proc", f"t{i}", now=0.0)
        assert led.requeue_site("proc") == 4
        assert led.inflight_at("proc") == 0
        # reserve: leave 4 fresh indices for the recovering site
        grabbed = led.take(100, fresh_floor=4)
        assert set(grabbed) >= {0, 1, 2, 3}          # recycled work comes first
        assert led.next_fresh == 6                   # 10 - 4 reserved

    def test_state_round_trip(self):
        led = WorkLedger(6)
        for i in led.take(4):
            led.on_submitted(i, "local", f"t{i}", now=0.0)
        led.accept(_delivery(1, "t1"))
        led.accept(_delivery(3, "t3"))
        clone = WorkLedger(6)
        clone.set_state(led.get_state())
        assert clone.completed == 2 and clone.next_fresh == 4
        assert sorted(clone.retry_q) == [0, 2]       # unfinished frontier requeued
        with pytest.raises(ValueError):
            WorkLedger(7).set_state(led.get_state())


class TestInvariantChecker:
    def _clean_ledger(self, n=3):
        led = WorkLedger(n)
        for i in led.take(n):
            led.on_submitted(i, "local", f"t{i}", now=0.0)
            led.accept(_delivery(i, f"t{i}"))
        return led

    def test_clean_run_passes(self):
        rep = InvariantChecker().check(self._clean_ledger())
        assert rep.ok and rep.lost == 0 and not rep.violations

    def test_lost_and_dup_fail(self):
        led = WorkLedger(3)
        led.take(3)
        led.on_submitted(0, "local", "t0", now=0.0)
        led.accept(_delivery(0, "t0"))
        led.accept(_delivery(0, "t0"))               # violation
        rep = InvariantChecker().check(led)
        assert not rep.ok
        assert rep.lost == 2 and rep.exactly_once_violations == 1
        assert any("never delivered" in v for v in rep.violations)
        assert any("duplicated" in v for v in rep.violations)

    def test_recovery_bound_and_unresolved_probes(self):
        led = self._clean_ledger()
        slow = RecoveryProbe(label="kill#1", scope="proc", t0=0.0)
        slow.resolve(5.0)
        never = RecoveryProbe(label="kill#2", scope="proc", t0=1.0)
        rep = InvariantChecker(recovery_bound_s=2.0).check(led, probes=[slow, never])
        assert not rep.ok
        assert any("took 5.00s > bound" in v for v in rep.violations)
        assert any("no proc-scope delivery" in v for v in rep.violations)
        assert rep.max_recovery_s == 5.0

    def test_require_faults(self):
        rep = InvariantChecker(require_faults=4).check(self._clean_ledger(), fired=[])
        assert not rep.ok and any("under fire" in v for v in rep.violations)


class TestSoakEndToEnd:
    def test_mini_soak_passes_invariant_gate(self):
        """End-to-end: a small soak through both sites with a site kill,
        a checkpoint corruption + resume drill, and a burst — the full
        acceptance path at test scale."""
        sched = ChaosSchedule([
            ChaosAction(kind="doom_workers", at_frac=0.05, params={"n": 2}, scope="local"),
            ChaosAction(kind="kill_site", at_frac=0.15, params={"site": "proc"}, scope="proc"),
            ChaosAction(kind="corrupt_checkpoint", at_frac=0.45, params={"mode": "bitflip"}, scope="none"),
            ChaosAction(kind="burst", at_frac=0.6, params={"n": 48}, scope="local"),
        ])
        cfg = SoakConfig(n_tasks=1500, deadline_s=120, recovery_bound_s=30.0,
                         checkpoint_every_s=0.25)
        res = SoakHarness(cfg, sched).run()
        assert res.report.ok, res.report.violations
        assert res.report.completed == 1500 and res.report.lost == 0
        assert res.report.exactly_once_violations == 0
        assert res.report.order_violations == 0
        assert res.metrics["site_kills"] == 1
        assert res.metrics["resume_drills"] == 1
        drill = next(f for f in res.fired if f.action.kind == "corrupt_checkpoint")
        assert drill.ok and drill.detail["fell_back"] and drill.detail["subset"]


class TestPartitionFault:
    def test_partition_drops_requests_and_holds_results(self):
        """During a partition nothing crosses in either direction: requests
        are dropped, finished results are held (delivered after heal)."""
        q = ChaosLocalQueues(chaos=ChaosLink(seed=3))
        server = TaskServer(q, {"f": lambda x: x}, n_workers=1).start()
        # A result finished before the cut is *held*, not lost.
        q.send_inputs(1, method="f")
        time.sleep(0.3)
        q.chaos.enable_partition(duration_s=0.5)
        assert q.get_result(timeout=0.1) is None
        # A request sent during the cut is dropped on the floor.
        q.send_inputs(2, method="f")
        assert q.chaos.partition_drops == 1
        # After heal the buffered result arrives; the dropped one never does.
        time.sleep(0.5)
        r = q.get_result(timeout=5)
        assert r is not None and r.value == 1
        assert q.get_result(timeout=0.3) is None
        server.stop()

    def test_disable_heals_partition_immediately(self):
        link = ChaosLink()
        link.enable_partition(duration_s=60.0)
        assert link.partitioned()
        link.disable()
        assert not link.partitioned()

    def test_partition_window_inert_after_pickle(self):
        import pickle

        link = ChaosLink()
        link.enable_partition(duration_s=60.0)
        clone = pickle.loads(pickle.dumps(link))
        assert not clone.partitioned()  # the child-side copy starts healed

    def test_kill_sentinel_crosses_a_partition(self):
        """Shutdown must survive a partition: the kill sentinel is never
        dropped, so a server stop during a cut still terminates."""
        q = ChaosLocalQueues(chaos=ChaosLink())
        server = TaskServer(q, {"f": lambda x: x}, n_workers=1).start()
        q.chaos.enable_partition(duration_s=30.0)
        t0 = time.monotonic()
        server.stop()
        assert time.monotonic() - t0 < 10.0


class TestSoakSLOGate:
    def test_slo_soak_fires_and_resolves_partition_alert(self):
        """The observe->steer loop under fire at test scale: a SIGKILL plus
        a partition must drive the burn-rate engine through fire AND
        resolve, with the remediation handlers recorded in the log."""
        sched = ChaosSchedule([
            ChaosAction(kind="kill_site", at_frac=0.2, params={"site": "proc"}, scope="proc"),
            ChaosAction(kind="partition", at_frac=0.4, params={"duration_s": 0.6}, scope="proc"),
        ])
        cfg = SoakConfig(n_tasks=2000, deadline_s=120, recovery_bound_s=30.0,
                         slo=True, seed=11)
        res = SoakHarness(cfg, sched).run()
        assert res.report.ok, res.report.violations
        assert res.metrics["alerts_fired"] >= 1
        assert res.metrics["alerts_resolved"] == res.metrics["alerts_fired"]
        assert res.metrics["alerts_unresolved"] == 0
        assert res.metrics["max_alert_resolve_s"] <= cfg.alert_resolve_bound_s
        part = next(f for f in res.fired if f.action.kind == "partition")
        assert part.ok and part.detail["deferred"] in (True, False)
        assert res.metrics["remediations"] >= 1


class TestControlPlaneFault:
    def test_schedule_driven_kill_control_plane(self):
        """A chaos schedule SIGKILLs a live control-plane-style daemon
        through the ``kill_control_plane`` primitive, and the firing is
        recorded ok; a second firing against the dead process reports
        the no-op instead of raising."""
        import subprocess
        import sys

        from repro.chaos import kill_control_plane

        proc = subprocess.Popen(
            [sys.executable, "-c", "import time; time.sleep(60)"],
            stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL,
        )
        sched = ChaosSchedule([
            ChaosAction(kind="kill_control_plane", at_s=0.0, scope="none"),
        ])
        runner = ChaosRunner(
            sched,
            handlers={
                "kill_control_plane": lambda params: {
                    "ok": kill_control_plane(proc) == proc.pid,
                    "pid": proc.pid,
                },
            },
        ).start()
        try:
            deadline = time.monotonic() + 10
            while not runner.fired and time.monotonic() < deadline:
                time.sleep(0.02)
        finally:
            runner.stop()
        assert len(runner.fired) == 1
        fired = runner.fired[0]
        assert fired.ok and fired.detail["pid"] == proc.pid
        assert proc.poll() is not None  # actually dead, reaped by the helper
        # idempotent on a dead process: no signal, no exception
        assert kill_control_plane(proc) is None
