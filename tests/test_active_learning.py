"""Integration tests: ActiveLearningThinker online loop, campaign
checkpoint/resume of surrogate state, and observe forward-compat with
the surrogate event kind."""

import time

import numpy as np
import pytest

from repro.core import (
    Campaign,
    LocalColmenaQueues,
    TaskServer,
    WorkerPool,
)
from repro.observe import Event, EventLog, MetricsAggregator, build_report, render_text
from repro.surrogate import (
    ActiveLearningThinker,
    DeepEnsemble,
    EnsembleConfig,
    make_policy,
    make_scenario,
    run_active_campaign,
    warmup_jit,
)

DIM = 3
CFG = EnsembleConfig(n_members=3, hidden=(16, 16), epochs=25, pad_to=64)


@pytest.fixture(scope="module", autouse=True)
def _warm_jit():
    """Compile the fit/predict graphs once for the whole module so no
    test's first retrain stalls on XLA."""
    warmup_jit(DIM, CFG, predict_rows=128)
    warmup_jit(DIM, CFG, predict_rows=256)


def _campaign_parts(candidates, scenario, *, max_results, seed=0, sleep_s=0.006):
    log = EventLog()
    queues = LocalColmenaQueues(topics=["simulate", "train"], event_log=log)
    pools = {"simulate": WorkerPool("simulate", 3), "ml": WorkerPool("ml", 1),
             "default": WorkerPool("default", 1)}

    def simulate(x, task_seed=0):
        time.sleep(sleep_s)
        return scenario.evaluate(x, task_seed)

    thinker = ActiveLearningThinker(
        queues,
        ensemble=DeepEnsemble(DIM, CFG, seed=seed),
        policy=make_policy("ucb"),
        candidates=candidates,
        n_slots=4,
        retrain_after=8,
        max_results=max_results,
        ml_slots=1,
        optimum_value=scenario.optimum_value,
        seed=seed,
    )
    thinker.rec.event_log = log
    server = TaskServer(queues, {"simulate": simulate}, pools=pools, event_log=log)
    return log, thinker, server


class TestActiveLearningThinker:
    def test_online_retrain_with_reallocation_and_telemetry(self):
        """The acceptance loop: >=2 online retrains visible in the observe
        report, slots shifted to the training pool during each retrain."""
        scenario = make_scenario("quadratic", dim=DIM)
        out = run_active_campaign(
            scenario, make_policy("ucb"), budget=32, retrain_after=8,
            n_candidates=128, seed=0, sim_sleep_s=0.006,
            ensemble=DeepEnsemble(DIM, CFG, seed=0),
        )
        report = out["report"]
        sur = report["surrogate"]
        assert sur["retrains"] >= 2
        assert len(sur["rmse"]) == sur["retrains"]
        assert all(r is not None for r in sur["regret"])
        # Every retrain shifted a slot into the training pool and back.
        moves = report["reallocations"]
        into_ml = [m for m in moves if m["dst"] == "ml"]
        back = [m for m in moves if m["src"] == "ml"]
        assert len(into_ml) >= 2 and len(back) >= 2
        # And the telemetry renders.
        text = render_text(report)
        assert "surrogate:" in text and "retrain" in text

    def test_steered_beats_random_on_quadratic(self):
        """Miniature of the benchmark/CI gate: exploitation on a smooth
        bowl must match or beat random search within the same budget."""
        scenario = make_scenario("quadratic", dim=DIM)
        kw = dict(budget=48, retrain_after=8, n_candidates=256, seed=0,
                  sim_sleep_s=0.006)
        steered = run_active_campaign(
            scenario, make_policy("greedy"),
            ensemble=DeepEnsemble(DIM, CFG, seed=0), **kw)
        random = run_active_campaign(
            scenario, make_policy("random"),
            ensemble=DeepEnsemble(DIM, CFG, seed=0), **kw)
        assert steered["hits"] >= random["hits"]

    def test_candidate_pool_never_resampled(self):
        """Joint selection + visited-set bookkeeping: no candidate is
        simulated twice even across multiple reranks."""
        scenario = make_scenario("multimodal", dim=DIM)
        out = run_active_campaign(
            scenario, make_policy("thompson"), budget=32, retrain_after=8,
            n_candidates=128, seed=1, sim_sleep_s=0.004,
            ensemble=DeepEnsemble(DIM, CFG, seed=1),
        )
        X, _ = out["thinker"].observed
        uniq = {tuple(np.round(x, 6)) for x in X}
        assert len(uniq) == len(X)


class TestCampaignResume:
    def test_killed_campaign_resumes_from_last_retrain(self, tmp_path):
        scenario = make_scenario("quadratic", dim=DIM)
        candidates = scenario.sample(np.random.default_rng(42), 256)

        # --- first run: killed mid-campaign by timeout -------------------
        log1, thinker1, server1 = _campaign_parts(
            candidates, scenario, max_results=None, sleep_s=0.02)
        camp1 = Campaign(thinker1, server1, state_dir=str(tmp_path),
                         checkpoint_interval_s=0.2, name="al")
        camp1.run(timeout=2.0)           # "kill": done forced while running
        assert camp1.checkpoints_written >= 1
        rounds1 = thinker1.train_rounds
        n1 = len(thinker1.observed[1])
        fits1 = thinker1.ensemble.fit_count
        assert rounds1 >= 1 and n1 >= 8

        # --- restart: a fresh thinker resumes from the checkpoint --------
        log2, thinker2, server2 = _campaign_parts(
            candidates, scenario, max_results=None, seed=7, sleep_s=0.004)
        camp2 = Campaign(thinker2, server2, state_dir=str(tmp_path),
                         checkpoint_interval_s=5.0, name="al")
        assert camp2.try_resume()
        # Continues from the last retrain, not from scratch:
        assert thinker2.train_rounds == rounds1
        assert thinker2.ensemble.fit_count == fits1 > 0
        n_resumed = len(thinker2.observed[1])
        assert n_resumed >= 8            # observed data survived the kill
        visited_before = set(thinker2._visited)
        assert visited_before            # queue position survived too

        thinker2.max_results = n_resumed + 16
        camp2.run(timeout=60, resume=False)
        X2, y2 = thinker2.observed
        assert len(y2) >= n_resumed + 16
        # The resumed run never re-simulates checkpointed candidates.
        assert visited_before <= set(thinker2._visited)
        assert len(thinker2._visited) > len(visited_before)
        # And keeps retraining the same ensemble onward.
        assert thinker2.ensemble.fit_count > fits1


class TestObserveForwardCompat:
    def test_report_tolerates_unknown_event_kinds(self):
        log = EventLog()
        log.gauge("slots", 2, pool="simulate")
        log.emit(Event(t=log.t0, kind="frobnicate", stage="warp", info={"x": 1}))
        log.emit(Event(t=log.t0, kind="frobnicate", stage="weft"))
        log.surrogate_event("retrain", value=0.5, round=1, n=8)
        log.surrogate_event("rerank", value=0.25, policy="ucb", k=4)
        report = build_report(log)
        assert report["unknown_kinds"] == {"frobnicate": 2}
        assert report["event_kinds"]["surrogate"] == 2
        text = render_text(report)
        assert "frobnicate x2" in text
        assert "surrogate:" in text

    def test_aggregator_counts_unknown_kinds(self):
        agg = MetricsAggregator()
        agg.observe(Event(t=0.0, kind="mystery", stage="s"))
        agg.observe(Event(t=1.0, kind="mystery", stage="s"))
        assert agg.unknown_kinds == {"mystery": 2}
        assert agg.makespan() == 1.0     # still contributes to the window

    def test_surrogate_stats_trajectories(self):
        log = EventLog()
        for i, rmse in enumerate((0.9, 0.5, 0.2)):
            log.surrogate_event("retrain", value=rmse, round=i + 1, n=8 * (i + 1))
            log.surrogate_event("rerank", value=1.0 - rmse, policy="ei", k=8)
        agg = MetricsAggregator(log)
        stats = agg.surrogate_stats()
        assert stats["retrains"] == 3
        assert stats["rmse"] == [0.9, 0.5, 0.2]
        assert stats["regret"] == pytest.approx([0.1, 0.5, 0.8])
        assert stats["policy"] == "ei"
        assert len(stats["retrain_cadence_s"]) == 2

    def test_render_text_tolerates_foreign_report_dicts(self):
        """A report from another build: missing sections, extra ones."""
        assert "makespan" in render_text({})
        foreign = {"makespan_s": 1.0, "events": 3, "mystery_section": {"a": 1},
                   "unknown_kinds": {"alien": 3}}
        text = render_text(foreign)
        assert "alien x3" in text


class TestEnsembleStreamCheckpoints:
    """Satellite: streaming (delta) ensemble checkpoints must be
    indistinguishable from the inline full-pickle format on resume."""

    @staticmethod
    def _fit_ensemble(seed=0):
        rng = np.random.default_rng(seed)
        X = rng.normal(size=(48, DIM)).astype(np.float32)
        y = (X ** 2).sum(axis=1).astype(np.float32)
        ens = DeepEnsemble(DIM, CFG)
        ens.fit(X, y)
        return ens, X

    @staticmethod
    def _thinker(ensemble, stream_dir=None):
        return ActiveLearningThinker(
            LocalColmenaQueues(),
            ensemble=ensemble,
            policy=make_policy("ucb"),
            candidates=np.random.default_rng(3).normal(size=(32, DIM)),
            n_slots=2,
            retrain_after=8,
            stream_dir=stream_dir,
        )

    def test_resume_parity_with_full_pickle(self, tmp_path):
        ens, X = self._fit_ensemble()
        full_state = self._thinker(ens).get_state()
        assert "ensemble" in full_state  # inline format unchanged by default

        streamer = self._thinker(ens, stream_dir=str(tmp_path / "stream"))
        stream_state = streamer.get_state()
        assert "ensemble" not in stream_state  # pickle carries a marker only
        marker = stream_state["ensemble_stream"]
        streamer._stream.wait()  # async write must land before the kill drill

        # resume both formats into fresh thinkers with cold ensembles
        t_full = self._thinker(DeepEnsemble(DIM, CFG))
        t_full.set_state(full_state)
        t_stream = self._thinker(DeepEnsemble(DIM, CFG))
        t_stream.set_state(stream_state)

        mf, sf = t_full.ensemble.predict(X)
        ms, ss = t_stream.ensemble.predict(X)
        assert np.allclose(mf, ms) and np.allclose(sf, ss)
        assert t_stream.ensemble.fit_count == ens.fit_count
        assert t_stream._rng.bit_generator.state == t_full._rng.bit_generator.state

        # a second save is a delta: unchanged leaves are pointers, and the
        # restored chain still verifies by content hash
        step2 = streamer._stream.save(streamer.ensemble)
        streamer._stream.wait()
        restored = streamer._stream.restore(step2)
        direct = streamer.ensemble.state_dict()
        flat_a = {k: np.asarray(v) for k, v in np.load(
            str(tmp_path / "stream" / f"step_{step2:08d}" / "shard_0.npz")).items()}
        assert len(flat_a) < 5  # nothing retrained: almost everything reused
        ref_mean, _ = streamer.ensemble.predict(X)
        cold = DeepEnsemble(DIM, CFG)
        cold.load_state_dict(restored)
        got_mean, _ = cold.predict(X)
        assert np.allclose(ref_mean, got_mean)
        assert direct["fit_count"] == restored["fit_count"]

    def test_restore_falls_back_when_marker_step_never_landed(self, tmp_path):
        ens, X = self._fit_ensemble(seed=7)
        streamer = self._thinker(ens, stream_dir=str(tmp_path / "s"))
        first = streamer.get_state()
        streamer._stream.wait()
        second = dict(first)
        # a marker pointing past the last durable step (SIGKILL between
        # checkpoint pickle publish and npz flush) resolves to the newest
        # step at or before it
        second["ensemble_stream"] = {
            "dir": first["ensemble_stream"]["dir"],
            "step": first["ensemble_stream"]["step"] + 3,
        }
        t = self._thinker(DeepEnsemble(DIM, CFG))
        t.set_state(second)
        mf, _ = ens.predict(X)
        ms, _ = t.ensemble.predict(X)
        assert np.allclose(mf, ms)
