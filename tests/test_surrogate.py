"""Unit tests for the repro.surrogate subsystem: deep-ensemble surrogate,
acquisition-policy analytics, and scenario calibration."""

import math

import numpy as np
import pytest

from repro.surrogate import (
    DeepEnsemble,
    EnsembleConfig,
    EpsilonRandom,
    ExpectedImprovement,
    Greedy,
    make_policy,
    make_scenario,
    Scenario,
    SCENARIOS,
    Thompson,
    UCB,
)


# ---------------------------------------------------------------------------
# DeepEnsemble
# ---------------------------------------------------------------------------


class TestDeepEnsemble:
    def _data(self, n=64, dim=3, seed=0):
        rng = np.random.default_rng(seed)
        X = rng.uniform(-1, 1, (n, dim))
        y = -((X - 0.3) ** 2).sum(axis=1)
        return X, y

    def test_fit_reduces_error_and_warm_start_continues(self):
        X, y = self._data()
        ens = DeepEnsemble(3, EnsembleConfig(epochs=40), seed=0)
        m1 = ens.fit(X, y)
        m2 = ens.fit(X, y)  # warm-start continuation
        assert m2["fit_count"] == 2
        assert m2["mse_norm"] < m1["mse_norm"]   # training continued, not reset
        m3 = ens.fit(X, y, warm_start=False)     # cold restart forgets
        assert m3["mse_norm"] > m2["mse_norm"]

    def test_predict_shapes_and_epistemic_uncertainty(self):
        X, y = self._data()
        ens = DeepEnsemble(3, EnsembleConfig(epochs=80), seed=0)
        ens.fit(X, y)
        mean, std = ens.predict(X)
        assert mean.shape == std.shape == (len(X),)
        assert np.all(std > 0)
        # Epistemic std must grow far outside the training support.
        far = np.full((8, 3), 4.0)
        _, std_far = ens.predict(far)
        assert std_far.mean() > std.mean() * 2

    def test_members_axis_is_ensemble(self):
        X, y = self._data(n=16)
        cfg = EnsembleConfig(n_members=5, epochs=10)
        ens = DeepEnsemble(3, cfg, seed=0)
        ens.fit(X, y)
        members = ens.predict_members(X)
        assert members.shape == (5, 16)
        # Members disagree (distinct inits + bootstrap) — std not collapsed.
        assert members.std(axis=0).mean() > 0

    def test_state_dict_roundtrip_preserves_predictions(self):
        X, y = self._data()
        ens = DeepEnsemble(3, EnsembleConfig(epochs=20), seed=0)
        ens.fit(X, y)
        state = ens.state_dict()
        clone = DeepEnsemble(3, EnsembleConfig(epochs=20), seed=99)
        clone.load_state_dict(state)
        np.testing.assert_allclose(clone.predict(X)[0], ens.predict(X)[0], rtol=1e-6)
        assert clone.fit_count == ens.fit_count
        with pytest.raises(ValueError):
            DeepEnsemble(7).load_state_dict(state)   # dim mismatch is loud

    def test_padding_preserves_results(self):
        """pad_to changes compile shapes, never predictions."""
        X, y = self._data(n=20)
        a = DeepEnsemble(3, EnsembleConfig(epochs=15, pad_to=None), seed=0)
        b = DeepEnsemble(3, EnsembleConfig(epochs=15, pad_to=256), seed=0)
        a.fit(X, y)
        b.fit(X, y)
        np.testing.assert_allclose(a.predict(X)[0], b.predict(X)[0], rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Acquisition policies (analytic sanity checks)
# ---------------------------------------------------------------------------


class TestAcquisition:
    def test_ei_zero_at_incumbent_with_zero_std(self):
        ei = ExpectedImprovement()
        mean = np.array([1.0, 0.5])
        std = np.array([0.0, 0.0])
        scores = ei.scores(mean, std, best_f=1.0, rng=np.random.default_rng(0))
        assert scores[0] == pytest.approx(0.0)          # at incumbent: no improvement
        assert scores[1] == pytest.approx(0.0)          # below it: none either
        # Positive deterministic improvement reduces to mean - best.
        scores = ei.scores(np.array([1.5]), np.array([0.0]), best_f=1.0,
                           rng=np.random.default_rng(0))
        assert scores[0] == pytest.approx(0.5)

    def test_ei_positive_under_uncertainty(self):
        ei = ExpectedImprovement()
        scores = ei.scores(np.array([1.0]), np.array([0.5]), best_f=1.0,
                           rng=np.random.default_rng(0))
        # At the incumbent mean with std>0, EI = std * pdf(0) > 0.
        assert scores[0] == pytest.approx(0.5 / math.sqrt(2 * math.pi), rel=1e-6)

    def test_ucb_monotone_in_beta(self):
        rng = np.random.default_rng(0)
        mean = rng.normal(size=32)
        std = rng.uniform(0.1, 1.0, 32)
        prev = None
        for beta in (0.0, 0.5, 1.0, 2.0, 4.0):
            s = UCB(beta).scores(mean, std, best_f=0.0, rng=rng)
            if prev is not None:
                assert np.all(s >= prev)                 # pointwise monotone
            prev = s
        # beta=0 degrades to greedy.
        np.testing.assert_allclose(UCB(0.0).scores(mean, std, best_f=0.0, rng=rng), mean)

    def test_thompson_hits_each_argmax_candidate_under_fixed_seeds(self):
        # Two well-separated modes: every posterior draw's argmax is one
        # of them; over many seeded draws both must be selected.
        mean = np.array([1.0, 1.0, -5.0, -5.0])
        std = np.array([1.0, 1.0, 0.01, 0.01])
        t = Thompson()
        picks = {t.select(1, mean, std, rng=np.random.default_rng(s))[0] for s in range(64)}
        assert picks == {0, 1}
        # With members given, draws come from member rows: a member whose
        # argmax is candidate 2 must surface under some seed.
        members = np.array([[1.0, 0.0, 0.0, 0.0],
                            [0.0, 1.0, 0.0, 0.0],
                            [0.0, 0.0, 1.0, 0.0]])
        picks = {
            t.select(1, mean, std, members=members,
                     rng=np.random.default_rng(s))[0]
            for s in range(64)
        }
        assert picks == {0, 1, 2}

    def test_batch_topk_is_joint_and_distinct(self):
        mean = np.array([0.0, 3.0, 2.0, 1.0, -1.0])
        std = np.full(5, 0.1)
        rng = np.random.default_rng(0)
        # Score-based policies: top-k without replacement, in rank order.
        assert Greedy().select(3, mean, std, rng=rng) == [1, 2, 3]
        # A pure repeated-top-1 selector would return [1, 1, 1].
        for policy in (Greedy(), UCB(), ExpectedImprovement(), Thompson(), EpsilonRandom()):
            picks = policy.select(4, mean, std, best_f=0.0,
                                  rng=np.random.default_rng(1))
            assert len(picks) == len(set(picks)) == 4, policy.name
        # exclude masks already-visited candidates for every policy.
        for policy in (Greedy(), UCB(), ExpectedImprovement(), Thompson(), EpsilonRandom()):
            picks = policy.select(2, mean, std, best_f=0.0,
                                  rng=np.random.default_rng(2), exclude={1, 2})
            assert not {1, 2} & set(picks), policy.name

    def test_epsilon_random_mixes(self):
        mean = np.linspace(0, 1, 100)
        std = np.full(100, 0.1)
        # eps=0 is pure greedy; eps=1 is uniform (first pick rarely argmax).
        assert EpsilonRandom(0.0).select(1, mean, std, rng=np.random.default_rng(0)) == [99]
        firsts = [EpsilonRandom(1.0).select(1, mean, std,
                                            rng=np.random.default_rng(s))[0]
                  for s in range(32)]
        assert len(set(firsts)) > 10

    def test_registry(self):
        for name in ("greedy", "ucb", "ei", "thompson", "random"):
            assert make_policy(name).select(
                1, np.array([0.0, 1.0]), np.array([0.1, 0.1]),
                rng=np.random.default_rng(0))
        with pytest.raises(ValueError):
            make_policy("nope")


# ---------------------------------------------------------------------------
# Scenarios
# ---------------------------------------------------------------------------


class TestScenarios:
    def test_registry_and_protocol(self):
        assert set(SCENARIOS) == {"quadratic", "multimodal", "needle", "heteroscedastic"}
        for name in SCENARIOS:
            sc = make_scenario(name, dim=3)
            assert isinstance(sc, Scenario)              # runtime protocol
            X = sc.sample(np.random.default_rng(0), 16)
            assert X.shape == (16, 3)
            assert np.all(X >= sc.lo) and np.all(X <= sc.hi)
            v = sc.true_value(X[0])
            assert np.isfinite(v)
            assert sc.threshold < sc.optimum_value

    def test_threshold_is_quantile_calibrated(self):
        """Random search has ~the same expected hit rate everywhere."""
        for name in SCENARIOS:
            sc = make_scenario(name, dim=3)
            X = sc.sample(np.random.default_rng(7), 4000)
            rate = (sc.true_batch(X) > sc.threshold).mean()
            assert 0.04 < rate < 0.12, (name, rate)

    def test_heteroscedastic_noise_is_seeded_and_state_dependent(self):
        sc = make_scenario("heteroscedastic", dim=3)
        x_near = np.full(3, 0.1)
        x_far = np.full(3, 0.9)
        assert sc.evaluate(x_near, seed=1) == sc.evaluate(x_near, seed=1)
        assert sc.evaluate(x_near, seed=1) != sc.evaluate(x_near, seed=2)
        spread = lambda x: np.std([sc.evaluate(x, seed=s) for s in range(64)])
        assert spread(x_far) > spread(x_near)            # noise grows off-optimum

    def test_needle_is_deceptive(self):
        """The broad hill's top must lie away from the global needle."""
        sc = make_scenario("needle", dim=3)
        hill_top = np.full(3, -0.5)
        needle_top = np.full(3, 0.55)
        assert sc.true_value(needle_top) > sc.true_value(hill_top)
        # Local gradient at the hill top points away from the needle:
        # stepping toward the needle from the hill decreases value.
        step = hill_top + 0.3 * (needle_top - hill_top) / np.linalg.norm(needle_top - hill_top)
        assert sc.true_value(step) < sc.true_value(hill_top)


class TestKrigingBeliever:
    """Hallucinated batch selection must diversify where plain top-k
    piles onto one peak."""

    @staticmethod
    def _bump_pool(n=101):
        # Narrow mean bump + large flat epistemic std: top-k of one
        # frozen UCB score hugs the bump (the mean tiebreaks identical
        # exploration terms), so batch diversity has to come from the
        # believer's std collapse around each pick.
        X = np.linspace(0.0, 1.0, n)[:, None]
        mean = np.exp(-0.5 * ((X[:, 0] - 0.5) / 0.03) ** 2)
        std = np.full(n, 1.0)
        return X, mean, std

    def test_spreads_where_plain_ucb_repeats_the_argmax_region(self):
        from repro.surrogate import KrigingBeliever

        X, mean, std = self._bump_pool()
        rng = np.random.default_rng(0)
        k = 5
        ucb = UCB(beta=2.0)
        plain = ucb.select(k, mean, std, rng=rng, X=X)
        kb = KrigingBeliever(base="ucb", lengthscale=0.15, beta=2.0)
        believed = kb.select(k, mean, std, rng=rng, X=X)

        assert len(set(plain)) == k and len(set(believed)) == k
        # both exploit the peak itself...
        assert int(np.argmax(mean)) in believed
        # ...but the degenerate batch hugs it while the believer spreads
        def min_gap(idx):
            xs = np.sort(X[idx, 0])
            return float(np.min(np.diff(xs)))
        assert min_gap(plain) < 0.05           # top-k of one frozen score: adjacent picks
        assert min_gap(believed) > min_gap(plain) * 2
        assert np.ptp(X[believed, 0]) > np.ptp(X[plain, 0])

    def test_without_coordinates_degrades_to_base_policy(self):
        from repro.surrogate import KrigingBeliever

        _, mean, std = self._bump_pool()
        base = UCB(beta=2.0)
        kb = KrigingBeliever(base=UCB(beta=2.0), lengthscale=0.1)
        assert kb.select(4, mean, std, rng=np.random.default_rng(1)) == \
            base.select(4, mean, std, rng=np.random.default_rng(1))

    def test_registry_and_validation(self):
        from repro.surrogate import KrigingBeliever

        p = make_policy("kriging", base="ei", lengthscale=0.2)
        assert isinstance(p, KrigingBeliever) and p.name == "kriging[ei]"
        with pytest.raises(ValueError):
            KrigingBeliever(lengthscale=0.0)

    def test_believed_incumbent_raises_best_f_for_ei(self):
        """After the first pick, EI must see the hallucinated incumbent:
        a candidate equal to the pick's mean with tiny std scores ~0."""
        from repro.surrogate import KrigingBeliever

        X = np.array([[0.0], [0.5], [1.0]])
        mean = np.array([1.0, 1.0, 0.2])
        std = np.array([1e-6, 1e-6, 0.5])
        kb = KrigingBeliever(base="ei", lengthscale=0.05)
        picks = kb.select(2, mean, std, best_f=0.0, rng=np.random.default_rng(0), X=X)
        # plain EI top-2 of one frozen score would take both 1.0-mean
        # twins; the believer's second pick prefers the uncertain point.
        assert picks[0] in (0, 1) and picks[1] == 2
