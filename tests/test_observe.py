"""Tests for repro.observe: event lifecycle completeness/ordering (incl.
under concurrent task servers), metrics aggregation on a synthetic trace,
reallocator policies, and the static-vs-adaptive acceptance comparison."""

import json
import threading
import time

import pytest

from repro.core import (
    LocalColmenaQueues,
    ResourceRequest,
    Result,
    ResourceCounter,
    TaskServer,
    WorkerPool,
)
from repro.observe import (
    AdaptiveReallocator,
    EMABacklogPolicy,
    Event,
    EventLog,
    GreedyBacklogPolicy,
    MetricsAggregator,
    PoolView,
    build_report,
    lifecycle_gaps,
    lifecycle_order_violations,
    render_text,
    run_two_pool,
)

REQUIRED = ("submitted", "queued", "picked_up", "dispatched", "running",
            "completed", "result_received")


def _run_tasks(log, n_tasks=12, n_servers=1, pools=("alpha", "beta")):
    """Push n_tasks through n_servers sharing one queue; drain results."""
    q = LocalColmenaQueues(event_log=log)
    servers = [
        TaskServer(
            q, {"work": lambda x: x * 2},
            pools={p: WorkerPool(p, 2) for p in (*pools, "default")},
        ).start()
        for _ in range(n_servers)
    ]
    for i in range(n_tasks):
        q.send_inputs(i, method="work",
                      resources=ResourceRequest(pool=pools[i % len(pools)]))
    results = [q.get_result(timeout=30) for _ in range(n_tasks)]
    for s in servers:
        s.stop()
    return q, results


class TestEventLifecycle:
    def test_full_lifecycle_recorded(self):
        log = EventLog()
        _, results = _run_tasks(log, n_tasks=10)
        assert all(r is not None and r.success for r in results)
        by_task = log.by_task()
        assert len(by_task) == 10
        for tid, evs in by_task.items():
            stages = [e.stage for e in evs]
            for s in REQUIRED:
                assert s in stages, f"{tid} missing {s}: {stages}"
        assert lifecycle_gaps(log) == {}
        assert lifecycle_order_violations(log) == []

    def test_lifecycle_under_concurrent_servers(self):
        log = EventLog()
        _, results = _run_tasks(log, n_tasks=24, n_servers=3)
        assert all(r is not None and r.success for r in results)
        assert lifecycle_gaps(log) == {}
        assert lifecycle_order_violations(log) == []
        # Each task is picked up by exactly one of the competing servers.
        counts = {}
        for ev in log.events():
            if ev.kind == "task" and ev.stage == "picked_up":
                counts[ev.task_id] = counts.get(ev.task_id, 0) + 1
        assert len(counts) == 24
        assert set(counts.values()) == {1}

    def test_failed_task_lifecycle(self):
        log = EventLog()
        q = LocalColmenaQueues(event_log=log)
        def boom(x):
            raise ValueError("nope")
        server = TaskServer(q, {"boom": boom}, n_workers=1).start()
        q.send_inputs(1, method="boom")
        r = q.get_result(timeout=30)
        server.stop()
        assert r is not None and not r.success
        stages = {e.stage for e in log.by_task()[r.task_id]}
        assert "failed" in stages and "completed" not in stages
        assert lifecycle_gaps(log) == {}

    def test_ring_buffer_capacity_and_jsonl_sink(self, tmp_path):
        path = tmp_path / "events.jsonl"
        log = EventLog(capacity=4, jsonl_path=str(path))
        for i in range(10):
            log.gauge("slots", i, pool="p")
        log.close()
        assert len(log) == 4  # ring keeps only the most recent
        assert [e.value for e in log.events()] == [6.0, 7.0, 8.0, 9.0]
        rows = [json.loads(line) for line in path.read_text().splitlines()]
        assert len(rows) == 10  # the sink keeps everything
        assert rows[0]["stage"] == "slots" and rows[0]["kind"] == "gauge"
        assert "t_rel" in rows[0]

    def test_subscribe_replays_buffered_events(self):
        log = EventLog()
        log.gauge("slots", 3, pool="p")
        seen = []
        log.subscribe(seen.append, replay=True)
        log.gauge("slots", 4, pool="p")
        assert [e.value for e in seen] == [3.0, 4.0]


def _task(tid, stage, t, pool="sim", method="work", **info):
    return Event(t=t, kind="task", stage=stage, task_id=tid,
                 method=method, topic="default", pool=pool, info=info)


class TestMetricsAggregation:
    def test_synthetic_trace_aggregation(self):
        agg = MetricsAggregator()
        # Two tasks on pool sim: compute 1.0s and 3.0s; one on ml: 2.0s.
        trace = []
        for tid, pool, t0, dur in (("a", "sim", 0.0, 1.0),
                                   ("b", "sim", 0.5, 3.0),
                                   ("c", "ml", 1.0, 2.0)):
            trace += [
                _task(tid, "submitted", t0, pool=pool),
                _task(tid, "queued", t0 + 0.01, pool=pool),
                _task(tid, "picked_up", t0 + 0.02, pool=pool),
                _task(tid, "dispatched", t0 + 0.1, pool=pool),
                _task(tid, "running", t0 + 0.2, pool=pool),
                _task(tid, "completed", t0 + 0.2 + dur, pool=pool),
                _task(tid, "result_received", t0 + 0.3 + dur, pool=pool),
            ]
        for ev in sorted(trace, key=lambda e: e.t):
            agg.observe(ev)

        pools = agg.pool_stats()
        assert pools["sim"].completed == 2
        assert pools["ml"].completed == 1
        assert pools["sim"].busy_seconds == pytest.approx(4.0)
        assert pools["ml"].busy_seconds == pytest.approx(2.0)
        assert pools["sim"].backlog == 0 and pools["sim"].running == 0

        methods = agg.method_stats()
        assert methods["work"]["count"] == 3
        assert methods["work"]["mean_s"] == pytest.approx(2.0)

        over = agg.overhead()
        assert over["queue"]["mean_s"] == pytest.approx(0.1)
        assert over["dispatch"]["mean_s"] == pytest.approx(0.1)
        assert over["compute"]["mean_s"] == pytest.approx(2.0)
        assert over["result"]["mean_s"] == pytest.approx(0.1)

        # makespan: first submit (t=0.0) to last result (b at 0.5+0.3+3.0)
        assert agg.makespan() == pytest.approx(3.8)
        util = agg.utilization(slots_by_pool={"sim": 2, "ml": 2})
        assert util["sim"] == pytest.approx(4.0 / (2 * 3.8))
        assert util["total"] == pytest.approx(6.0 / (4 * 3.8))

    def test_backlog_tracks_submitted_not_running(self):
        agg = MetricsAggregator()
        agg.observe(_task("a", "submitted", 0.0))
        agg.observe(_task("b", "submitted", 0.1))
        assert agg.backlog("sim") == 2
        agg.observe(_task("a", "running", 0.2, info={}))
        assert agg.backlog("sim") == 1

    def test_speculative_twin_not_double_counted(self):
        agg = MetricsAggregator()
        agg.observe(_task("a", "submitted", 0.0))
        agg.observe(_task("a", "running", 1.0, worker_id=0))
        agg.observe(_task("a", "speculated", 5.0))
        agg.observe(_task("a", "running", 5.1, worker_id=1))      # twin
        agg.observe(_task("a", "completed", 6.1, worker_id=1))    # twin wins
        agg.observe(_task("a", "result_received", 6.2))
        agg.observe(_task("a", "decision_made", 6.3))
        agg.observe(_task("a", "completed", 7.0, worker_id=0))    # late loser
        st = agg.pool_stats()["sim"]
        assert st.completed == 1           # one task, not one per copy
        assert st.running == 0             # both copies retired
        # busy time covers BOTH copies' real worker occupancy
        assert st.busy_seconds == pytest.approx((6.1 - 5.1) + (7.0 - 1.0))
        assert agg.method_stats()["work"]["count"] == 1
        # transient per-task state fully dropped (no leak from the
        # decision_made / late-loser events arriving after result_received)
        assert agg._marks == {} and agg._run_start == {}

    def test_capacity_integral_from_slot_gauges(self):
        agg = MetricsAggregator()
        agg.observe(Event(t=0.0, kind="gauge", stage="slots", pool="sim", value=4))
        agg.observe(Event(t=10.0, kind="gauge", stage="slots", pool="sim", value=2))
        agg.observe(_task("x", "submitted", 20.0))
        # 4 slots for 10 s + 2 slots for 10 s = 60 slot-seconds
        assert agg.capacity_slot_seconds("sim", until=20.0) == pytest.approx(60.0)


class TestReallocator:
    def test_greedy_shifts_toward_backlogged_pool(self):
        rec = ResourceCounter(4, pools=["a", "b"])  # all 4 slots in "a"
        backlog = {"a": 0, "b": 5}
        r = AdaptiveReallocator(rec, pools=["a", "b"],
                                policy=GreedyBacklogPolicy(),
                                backlog=lambda p: backlog[p])
        assert r.step() is True
        assert rec.allocation("b") == 4  # all idle slots migrate at once
        assert rec.allocation("a") == 0
        assert r.step() is False  # nothing left to move

    def test_min_slots_floor_respected(self):
        rec = ResourceCounter(4, pools=["a", "b"])
        r = AdaptiveReallocator(rec, pools=["a", "b"],
                                policy=GreedyBacklogPolicy(),
                                backlog=lambda p: 9 if p == "b" else 0,
                                min_slots={"a": 3})
        r.step()
        assert rec.allocation("a") == 3
        assert rec.allocation("b") == 1

    def test_busy_slots_never_move(self):
        rec = ResourceCounter(2, pools=["a", "b"])
        assert rec.acquire("a", 2, timeout=1)  # both slots busy
        r = AdaptiveReallocator(rec, pools=["a", "b"],
                                policy=GreedyBacklogPolicy(),
                                backlog=lambda p: 5 if p == "b" else 0,
                                acquire_timeout=0.01)
        assert r.step() is False
        assert rec.allocation("a") == 2

    def test_ema_policy_has_hysteresis(self):
        policy = EMABacklogPolicy(alpha=1.0, hysteresis=1.0)
        views = [PoolView("a", allocation=2, free=1, backlog=0),
                 PoolView("b", allocation=2, free=0, backlog=1)]
        assert policy.decide(views) is None  # gap too small: no thrash
        views[1] = PoolView("b", allocation=2, free=0, backlog=8)
        mv = policy.decide(views)
        assert mv is not None and mv.src == "a" and mv.dst == "b" and mv.n == 1

    def test_resource_counter_allocation_tracking(self):
        rec = ResourceCounter(6, pools=["x", "y"])
        assert rec.allocations() == {"x": 6, "y": 0}
        rec.reallocate("x", "y", 2)
        assert rec.allocations() == {"x": 4, "y": 2}
        assert rec.acquire("y", 1, timeout=1)
        assert rec.allocation("y") == 2  # acquire does not change allocation
        rec.grow("y", 3)
        assert rec.allocations() == {"x": 4, "y": 5}
        assert rec.shrink("x", 4, timeout=1)
        assert rec.allocations() == {"x": 0, "y": 5}


class TestAdaptiveBeatsStatic:
    """The acceptance comparison: on the imbalanced two-pool workload the
    AdaptiveReallocator must reach at least the static split's
    utilization, with a complete lifecycle trace for every task."""

    @pytest.fixture(scope="class")
    def runs(self):
        static, _, _ = run_two_pool(
            n_slots=6, n_sim=30, n_ml=5, task_s=0.03, adaptive=False)
        adaptive, log, thinker = run_two_pool(
            n_slots=6, n_sim=30, n_ml=5, task_s=0.03, adaptive=True)
        return static, adaptive, log, thinker

    def test_all_tasks_complete(self, runs):
        static, adaptive, _, thinker = runs
        assert static["pools"]["sim"]["completed"] == 30
        assert static["pools"]["ml"]["completed"] == 5
        assert adaptive["pools"]["sim"]["completed"] == 30
        assert adaptive["pools"]["ml"]["completed"] == 5
        assert len(thinker.results) == 35

    def test_adaptive_utilization_at_least_static(self, runs):
        static, adaptive, _, _ = runs
        # The static split strands the ml slots once ml work drains
        # (~half the slots idle for most of the run), so adaptive wins by
        # a wide margin — the >= assertion is robust to scheduling noise.
        assert adaptive["utilization"]["total"] >= static["utilization"]["total"]

    def test_reallocation_happened(self, runs):
        _, adaptive, _, thinker = runs
        assert thinker.reallocator is not None
        assert len(thinker.reallocator.moves) >= 1
        assert adaptive["reallocations"]  # recorded in the event log too
        assert all(m["dst"] == "sim" for m in adaptive["reallocations"])

    def test_event_log_has_every_lifecycle_stage(self, runs):
        _, _, log, _ = runs
        assert lifecycle_gaps(log) == {}
        assert lifecycle_order_violations(log) == []
        by_task = log.by_task()
        assert len(by_task) == 35
        for tid, evs in by_task.items():
            stages = {e.stage for e in evs}
            missing = [s for s in REQUIRED if s not in stages]
            assert not missing, f"{tid} missing {missing}"


class TestReportRendering:
    def test_build_and_render(self):
        log = EventLog()
        _run_tasks(log, n_tasks=6)
        report = build_report(log, total_slots=4)
        assert report["lifecycle"]["complete"]
        assert report["stage_counts"]["completed"] == 6
        assert 0 < report["utilization"]["total"] <= 1.0
        text = render_text(report)
        assert "lifecycle:       complete & ordered" in text
        assert "overhead breakdown" in text
        assert "task spans" in text  # Fig.-7-style span breakdown folded in


def _lifecycle(tid, t0=0.0, pool="sim", method="work", fail=False, **info):
    """A complete synthetic lifecycle for one task, 0.1 s per hop."""
    stages = ["submitted", "queued", "picked_up", "dispatched", "running",
              "failed" if fail else "completed", "result_received",
              "decision_made"]
    return [_task(tid, s, t0 + 0.1 * i, pool=pool, method=method, **info)
            for i, s in enumerate(stages)]


class TestSpanBuilder:
    def test_full_lifecycle_yields_all_six_spans(self):
        from repro.observe import build_task_traces, span_summary

        traces = build_task_traces(_lifecycle("a"))
        assert len(traces) == 1
        tr = traces[0]
        assert [s.name for s in tr.spans] == [
            "queue-wait", "pickup", "dispatch", "run",
            "result-wait", "decision"]
        # submitted -> picked_up is two hops; every other span is one.
        assert tr.critical == "queue-wait"
        assert tr.ok and not tr.flags
        summary = span_summary(traces)
        assert summary["tasks"] == 1 and summary["flagged"] == 0
        assert summary["critical_path"] == {"queue-wait": 1}
        assert summary["spans"]["run"]["mean_s"] == pytest.approx(0.1)

    def test_missing_stages_degrade_gracefully(self):
        from repro.observe import build_task_traces

        evs = [_task("a", "submitted", 0.0), _task("a", "picked_up", 0.2)]
        (tr,) = build_task_traces(evs)
        assert [s.name for s in tr.spans] == ["queue-wait"]
        assert not tr.flags

    def test_out_of_order_pair_flagged_not_negative(self):
        from repro.observe import build_task_traces

        evs = _lifecycle("a")
        # Clock skew: running recorded before its dispatched.
        evs[4] = _task("a", "running", 0.25)   # dispatched is at 0.3
        evs[3] = _task("a", "dispatched", 0.3)
        (tr,) = build_task_traces(evs)
        assert "out-of-order:dispatch" in tr.flags
        assert all(s.duration >= 0 for s in tr.spans)

    def test_failed_task_run_span_ends_at_failed(self):
        from repro.observe import build_task_traces

        (tr,) = build_task_traces(_lifecycle("a", fail=True))
        assert not tr.ok
        names = [s.name for s in tr.spans]
        assert "run" in names and "result-wait" in names

    def test_trace_context_rides_events_and_retry_links(self):
        from repro.core import TraceContext
        from repro.observe import build_task_traces

        ctx = TraceContext.new()
        child = ctx.child()
        assert child.trace_id == ctx.trace_id
        assert child.parent_span_id == ctx.span_id
        assert child.span_id != ctx.span_id
        (tr,) = build_task_traces(_lifecycle("a", **ctx.as_dict()))
        assert tr.trace_id == ctx.trace_id and tr.span_id == ctx.span_id

    def test_results_carry_trace_context_end_to_end(self):
        log = EventLog()
        _, results = _run_tasks(log, n_tasks=4)
        assert all(r.trace is not None for r in results)
        assert len({r.trace.trace_id for r in results}) == 4
        for ev in log.events():
            if ev.kind == "task":
                assert "trace_id" in ev.info

    def test_perfetto_export_shape(self, tmp_path):
        from repro.observe import export_perfetto

        log = EventLog(jsonl_path=str(tmp_path / "ev.jsonl"))
        _run_tasks(log, n_tasks=3)
        log.profile("kernel.x", t_start=0.5, wall_s=0.01, device_s=0.004)
        log.close()
        doc = export_perfetto(str(tmp_path / "ev.jsonl"),
                              str(tmp_path / "trace.json"))
        xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
        assert len([e for e in xs if e["cat"] == "task"]) >= 3 * 5
        assert len([e for e in xs if e["cat"] == "profile"]) == 1
        assert all(e["dur"] >= 0 and e["ts"] >= 0 for e in xs)
        metas = [e for e in doc["traceEvents"] if e["ph"] == "M"]
        assert any(e["name"] == "process_name" for e in metas)
        assert json.loads((tmp_path / "trace.json").read_text())


def _fed_double(x):
    return x * 2


class TestFederatedTrace:
    """The federated observability acceptance: a spawned-server run
    (ServerSpec(in_process=False)) writes parent + child JSONL logs that
    merge into one causal trace with zero lifecycle gaps."""

    def test_merged_cross_process_trace_is_complete(self, tmp_path):
        from repro.app import (
            AppSpec, ColmenaApp, ObserveSpec, QueueSpec, ServerSpec,
        )
        from repro.observe import build_task_traces, merge_jsonl

        jsonl = str(tmp_path / "events.jsonl")
        spec = AppSpec(
            tasks={"double": _fed_double},
            queues=QueueSpec(backend="pipe"),
            pools={"default": 2},
            server=ServerSpec(in_process=False),
            observe=ObserveSpec(jsonl_path=jsonl),
        )
        server_jsonl = spec.observe.resolved_server_jsonl()
        app = ColmenaApp(spec)
        with app.run(timeout=120) as handle:
            for i in range(6):
                handle.queues.send_inputs(i, method="double")
            results = [handle.queues.get_result(timeout=60) for _ in range(6)]
        assert all(r is not None and r.success for r in results)

        merged = EventLog(capacity=1 << 18)
        for ev in merge_jsonl([jsonl, server_jsonl]):
            merged.emit(ev)
        assert lifecycle_gaps(merged) == {}
        assert lifecycle_order_violations(merged) == []
        traces = build_task_traces(merged)
        assert len(traces) == 6
        for tr in traces:
            assert tr.trace_id is not None
            sites = {s.site for s in tr.spans}
            assert len(sites) == 2  # spans land on both sides of the pipe


class TestEventLogDurability:
    def test_jsonl_lines_visible_before_close(self, tmp_path):
        """Line-buffered sink: a kill -9'd child's log is still readable."""
        path = tmp_path / "ev.jsonl"
        log = EventLog(jsonl_path=str(path))
        log.gauge("slots", 1, pool="p")
        log.gauge("slots", 2, pool="p")
        rows = [json.loads(l) for l in path.read_text().splitlines()]
        assert len(rows) == 2  # visible without close()
        log.close()

    def test_close_is_idempotent(self, tmp_path):
        log = EventLog(jsonl_path=str(tmp_path / "ev.jsonl"))
        log.gauge("slots", 1, pool="p")
        log.close()
        log.close()

    def test_torn_tail_line_skipped_on_load(self, tmp_path):
        from repro.observe import load_jsonl

        path = tmp_path / "ev.jsonl"
        log = EventLog(jsonl_path=str(path))
        log.gauge("slots", 1, pool="p")
        log.close()
        with open(path, "a") as fh:
            fh.write('{"t": 1.0, "kind": "gau')  # SIGKILL mid-write
        events = load_jsonl(str(path))
        assert len(events) == 1 and events[0].value == 1.0
        assert events[0].info["site"] == "ev"

    def test_size_based_rotation(self, tmp_path):
        path = tmp_path / "ev.jsonl"
        log = EventLog(jsonl_path=str(path), rotate_bytes=2048, rotate_keep=2)
        for i in range(200):
            log.gauge("slots", i, pool="p")
        log.close()
        assert path.exists()
        assert (tmp_path / "ev.jsonl.1").exists()
        # Every generation holds valid JSONL; total rows capped by keep.
        for p in (path, tmp_path / "ev.jsonl.1"):
            for line in p.read_text().splitlines():
                json.loads(line)


class TestArrivalRateScaling:
    """Satellite: the ElasticScaler folds the event-log arrival rate into
    its sizing decisions so fleets pre-grow ahead of bursts."""

    def _scaler(self, log, n=1, lo=1, hi=8, **policy_kw):
        from repro.app import PoolSpec
        from repro.observe import ElasticPolicy, ElasticScaler

        spec = PoolSpec("p", size=n, min_size=lo, max_size=hi, warm_capacity=0)
        pool = spec.build(event_log=log)
        policy = ElasticPolicy(idle_grace_ticks=1, **policy_kw)
        scaler = ElasticScaler({"p": pool}, {"p": spec},
                               policy=policy, event_log=log)
        return pool, scaler

    def test_dispatched_events_feed_rate_ema(self):
        log = EventLog()
        pool, scaler = self._scaler(log)
        scaler._update_rates()          # arm the clock
        for i in range(10):
            log.emit(_task(f"t{i}", "dispatched", float(i), pool="p"))
        time.sleep(0.05)
        scaler._update_rates()
        assert scaler._rate_ema["p"] > 0
        assert scaler.expected_arrivals("p") > 0
        gauges = [e for e in log.events()
                  if e.kind == "gauge" and e.stage == "arrival_rate"]
        assert gauges and gauges[-1].pool == "p"
        scaler.stop()
        pool.shutdown()

    def test_pre_grow_ahead_of_queue(self):
        """High arrival rate + empty queue still grows the fleet."""
        log = EventLog()
        pool, scaler = self._scaler(log, n=1)
        scaler._rate_ema["p"] = 100.0   # 100 tasks/s smoothed
        scaler._rate_t = time.monotonic()
        target = scaler._decide("p", pool)
        assert target is not None and target > pool.n_workers
        pool.shutdown()
        scaler.stop()

    def test_expected_arrivals_hold_capacity(self):
        """Imminent arrivals reset the idle clock instead of shrinking."""
        log = EventLog()
        pool, scaler = self._scaler(log, n=2)
        scaler._rate_ema["p"] = 3.0     # ~0.6 expected in the window
        scaler._idle_ticks["p"] = 5
        assert scaler._decide("p", pool) is None
        assert scaler._idle_ticks["p"] == 0
        # Rate decays to zero: the idle-grace shrink path resumes.
        scaler._rate_ema["p"] = 0.0
        target = None
        for _ in range(3):
            target = scaler._decide("p", pool)
            if target is not None:
                break
        assert target is not None and target < 2
        pool.shutdown()
        scaler.stop()

    def test_rebind_moves_subscription(self):
        log1, log2 = EventLog(), EventLog()
        _, scaler = self._scaler(log1)
        scaler.rebind_event_log(log2)
        log1.emit(_task("a", "dispatched", 0.0, pool="p"))
        log2.emit(_task("b", "dispatched", 0.0, pool="p"))
        assert scaler._arrival_counts["p"] == 1  # only log2 counted
        scaler.stop()


class TestMetricsExport:
    def test_prometheus_text_format(self):
        log = EventLog()
        _run_tasks(log, n_tasks=5)
        agg = MetricsAggregator(log)
        text = agg.prometheus_text(slots_by_pool={"alpha": 2, "beta": 2})
        assert "# TYPE repro_pool_completed counter" in text
        assert 'repro_pool_completed{pool="alpha"} 3' in text
        assert "repro_makespan_seconds" in text
        assert 'repro_pool_utilization{pool="total"}' in text
        assert 'repro_method_latency_seconds{method="work",quantile="0.5"}' in text
        assert text.endswith("\n")
        for line in text.splitlines():
            assert line.startswith("#") or " " in line

    def test_snapshot_is_json_safe(self):
        log = EventLog()
        _run_tasks(log, n_tasks=4)
        log.profile("kernel.x", t_start=0.0, wall_s=0.01)
        agg = MetricsAggregator(log)
        snap = agg.snapshot(slots_by_pool={"alpha": 2})
        doc = json.loads(json.dumps(snap))
        assert doc["methods"]["work"]["count"] == 4
        assert doc["profiles"]["kernel.x"]["count"] == 1

    def test_exporter_writes_prom_and_snapshot(self, tmp_path):
        from repro.observe import ExportSpec, MetricsExporter

        log = EventLog()
        _run_tasks(log, n_tasks=3)
        exporter = MetricsExporter(
            log, spec=ExportSpec(dir=str(tmp_path), interval_s=60),
            slots_by_pool={"alpha": 2, "beta": 2})
        exporter.write_once()
        prom = (tmp_path / "metrics.prom").read_text()
        assert "repro_pool_completed" in prom
        snap = json.loads((tmp_path / "snapshot.json").read_text())
        assert snap["methods"]["work"]["count"] == 3
        assert "ts" in snap

    def test_exporter_background_thread(self, tmp_path):
        from repro.observe import ExportSpec, MetricsExporter

        log = EventLog()
        exporter = MetricsExporter(
            log, spec=ExportSpec(dir=str(tmp_path), interval_s=0.05))
        exporter.start()
        _run_tasks(log, n_tasks=2)
        time.sleep(0.15)
        exporter.stop()
        snap = json.loads((tmp_path / "snapshot.json").read_text())
        assert snap["methods"]["work"]["count"] == 2

    def test_exporter_rebind_no_double_count(self, tmp_path):
        """After ``rebind`` the exporter must aggregate only the new log:
        late events still arriving on the old one are another run's."""
        from repro.observe import ExportSpec, MetricsExporter

        log1 = EventLog()
        _run_tasks(log1, n_tasks=4)
        exporter = MetricsExporter(log1, spec=ExportSpec(dir=str(tmp_path)))
        exporter.write_once()
        assert json.loads((tmp_path / "snapshot.json").read_text())[
            "methods"]["work"]["count"] == 4
        log2 = EventLog()
        exporter.rebind(log2)
        _run_tasks(log1, n_tasks=5)  # late arrivals on the old log: ignored
        _run_tasks(log2, n_tasks=2)
        exporter.write_once()
        snap = json.loads((tmp_path / "snapshot.json").read_text())
        assert snap["methods"]["work"]["count"] == 2

    def test_jsonl_rotation_no_double_count(self, tmp_path):
        """Every event lands in exactly one rotated generation — loading
        all generations back recovers each task lifecycle exactly once."""
        from repro.observe.trace import load_jsonl

        path = tmp_path / "ev.jsonl"
        log = EventLog(jsonl_path=str(path), rotate_bytes=4096, rotate_keep=8)
        _, results = _run_tasks(log, n_tasks=24)
        assert all(r.success for r in results)
        log.close()
        generations = sorted(tmp_path.glob("ev.jsonl*"))
        assert len(generations) >= 2, "rotation never triggered"
        events = [ev for g in generations for ev in load_jsonl(str(g))]
        received = [ev for ev in events if ev.stage == "result_received"]
        assert len(received) == 24
        assert len({ev.task_id for ev in received}) == 24

    def test_observe_spec_export_knob(self, tmp_path):
        from repro.app import AppSpec, ColmenaApp, ObserveSpec

        app = ColmenaApp(AppSpec(
            tasks={"double": _fed_double},
            pools={"default": 2},
            observe=ObserveSpec(export=str(tmp_path)),
        ))
        with app.run(timeout=60) as handle:
            handle.queues.send_inputs(3, method="double")
            assert handle.queues.get_result(timeout=30).success
        assert (tmp_path / "metrics.prom").exists()
        assert json.loads((tmp_path / "snapshot.json").read_text())


class TestBenchTrajectory:
    def test_recorder_writes_schema(self, tmp_path):
        from repro.observe import BenchRecorder, load_bench

        rec = BenchRecorder("demo", out_dir=str(tmp_path))
        rec.metric("speedup_x", 3.2, unit="x", gate=(">=", 2.0))
        rec.metric("latency_us", 120.0, unit="us")
        path = rec.finish(ok=True)
        doc = load_bench(path)
        assert doc["name"] == "demo" and doc["schema"] == 1
        assert doc["metrics"]["speedup_x"]["passed"] is True
        assert doc["gates_passed"] and doc["passed"]
        assert "python" in doc["env"]
        assert doc["commit"] is None or len(doc["commit"]) == 40

    def test_failed_gate_fails_suite(self, tmp_path):
        from repro.observe import BenchRecorder, load_bench

        rec = BenchRecorder("demo", out_dir=str(tmp_path))
        rec.metric("speedup_x", 1.1, unit="x", gate=(">=", 2.0))
        doc = load_bench(rec.finish(ok=True))
        assert doc["metrics"]["speedup_x"]["passed"] is False
        assert not doc["gates_passed"] and not doc["passed"]

    def test_diff_regression_direction(self):
        from repro.observe import bench_diff

        old = {"name": "demo", "commit": "a" * 40, "metrics": {
            "speedup_x": {"value": 3.0, "gate": {"op": ">=", "threshold": 2.0}},
            "latency_us": {"value": 100.0, "gate": {"op": "<=", "threshold": 500.0}},
            "free": {"value": 1.0},
        }}
        new = {"name": "demo", "commit": "b" * 40, "metrics": {
            "speedup_x": {"value": 2.0, "gate": {"op": ">=", "threshold": 2.0}},
            "latency_us": {"value": 90.0, "gate": {"op": "<=", "threshold": 500.0}},
            "free": {"value": 5.0},
        }}
        diff = bench_diff(old, new)
        assert diff["metrics"]["speedup_x"]["status"] == "regressed"
        assert diff["metrics"]["latency_us"]["status"] == "improved"
        assert diff["metrics"]["free"]["status"] == "changed"  # ungated
        assert diff["regressions"] == ["speedup_x"] and not diff["ok"]

    def test_diff_within_tolerance_unchanged(self):
        from repro.observe import bench_diff

        old = {"name": "d", "metrics": {"x": {"value": 100.0, "gate": {"op": ">=", "threshold": 1}}}}
        new = {"name": "d", "metrics": {"x": {"value": 97.0, "gate": {"op": ">=", "threshold": 1}}}}
        diff = bench_diff(old, new, rel_tol=0.05)
        assert diff["metrics"]["x"]["status"] == "unchanged" and diff["ok"]

    def test_render_and_cli_diff(self, tmp_path, capsys):
        from repro.observe import BenchRecorder, render_diff
        from repro.observe.__main__ import main as cli_main
        from repro.observe.bench import diff_paths

        for d, val in (("old", 4.0), ("new", 1.5)):
            rec = BenchRecorder("demo", out_dir=str(tmp_path / d))
            rec.metric("speedup_x", val, unit="x", gate=(">=", 2.0))
            rec.finish(ok=True)
        old = str(tmp_path / "old" / "BENCH_demo.json")
        new = str(tmp_path / "new" / "BENCH_demo.json")
        text = render_diff(diff_paths(old, new))
        assert "REGRESSED: speedup_x" in text
        assert cli_main(["bench", "diff", old, new]) == 0  # soft by default
        assert cli_main(["bench", "diff", old, new, "--fail-on-regress"]) == 1
        assert cli_main(["bench", "diff",
                         str(tmp_path / "old"), str(tmp_path / "new"),
                         "--fail-on-regress"]) == 1
        capsys.readouterr()

    def test_specfile_roundtrip_observe_knobs(self, tmp_path):
        from repro.app import AppSpec, ObserveSpec
        from repro.core.specfile import spec_from_dict, spec_to_dict

        spec = AppSpec(
            tasks={"double": _fed_double},
            observe=ObserveSpec(
                jsonl_path="ev.jsonl", rotate_bytes=1 << 20, rotate_keep=2,
                export={"dir": "obs", "interval_s": 2.0}),
        )
        d = spec_to_dict(spec)
        assert d["observe"]["rotate_bytes"] == 1 << 20
        assert d["observe"]["export"]["dir"] == "obs"
        back = spec_from_dict(d)
        assert back.observe.rotate_bytes == 1 << 20
        assert back.observe.resolved_server_jsonl() == "ev.server.jsonl"
