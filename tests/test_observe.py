"""Tests for repro.observe: event lifecycle completeness/ordering (incl.
under concurrent task servers), metrics aggregation on a synthetic trace,
reallocator policies, and the static-vs-adaptive acceptance comparison."""

import json
import threading
import time

import pytest

from repro.core import (
    LocalColmenaQueues,
    ResourceRequest,
    Result,
    ResourceCounter,
    TaskServer,
    WorkerPool,
)
from repro.observe import (
    AdaptiveReallocator,
    EMABacklogPolicy,
    Event,
    EventLog,
    GreedyBacklogPolicy,
    MetricsAggregator,
    PoolView,
    build_report,
    lifecycle_gaps,
    lifecycle_order_violations,
    render_text,
    run_two_pool,
)

REQUIRED = ("submitted", "queued", "picked_up", "dispatched", "running",
            "completed", "result_received")


def _run_tasks(log, n_tasks=12, n_servers=1, pools=("alpha", "beta")):
    """Push n_tasks through n_servers sharing one queue; drain results."""
    q = LocalColmenaQueues(event_log=log)
    servers = [
        TaskServer(
            q, {"work": lambda x: x * 2},
            pools={p: WorkerPool(p, 2) for p in (*pools, "default")},
        ).start()
        for _ in range(n_servers)
    ]
    for i in range(n_tasks):
        q.send_inputs(i, method="work",
                      resources=ResourceRequest(pool=pools[i % len(pools)]))
    results = [q.get_result(timeout=30) for _ in range(n_tasks)]
    for s in servers:
        s.stop()
    return q, results


class TestEventLifecycle:
    def test_full_lifecycle_recorded(self):
        log = EventLog()
        _, results = _run_tasks(log, n_tasks=10)
        assert all(r is not None and r.success for r in results)
        by_task = log.by_task()
        assert len(by_task) == 10
        for tid, evs in by_task.items():
            stages = [e.stage for e in evs]
            for s in REQUIRED:
                assert s in stages, f"{tid} missing {s}: {stages}"
        assert lifecycle_gaps(log) == {}
        assert lifecycle_order_violations(log) == []

    def test_lifecycle_under_concurrent_servers(self):
        log = EventLog()
        _, results = _run_tasks(log, n_tasks=24, n_servers=3)
        assert all(r is not None and r.success for r in results)
        assert lifecycle_gaps(log) == {}
        assert lifecycle_order_violations(log) == []
        # Each task is picked up by exactly one of the competing servers.
        counts = {}
        for ev in log.events():
            if ev.kind == "task" and ev.stage == "picked_up":
                counts[ev.task_id] = counts.get(ev.task_id, 0) + 1
        assert len(counts) == 24
        assert set(counts.values()) == {1}

    def test_failed_task_lifecycle(self):
        log = EventLog()
        q = LocalColmenaQueues(event_log=log)
        def boom(x):
            raise ValueError("nope")
        server = TaskServer(q, {"boom": boom}, n_workers=1).start()
        q.send_inputs(1, method="boom")
        r = q.get_result(timeout=30)
        server.stop()
        assert r is not None and not r.success
        stages = {e.stage for e in log.by_task()[r.task_id]}
        assert "failed" in stages and "completed" not in stages
        assert lifecycle_gaps(log) == {}

    def test_ring_buffer_capacity_and_jsonl_sink(self, tmp_path):
        path = tmp_path / "events.jsonl"
        log = EventLog(capacity=4, jsonl_path=str(path))
        for i in range(10):
            log.gauge("slots", i, pool="p")
        log.close()
        assert len(log) == 4  # ring keeps only the most recent
        assert [e.value for e in log.events()] == [6.0, 7.0, 8.0, 9.0]
        rows = [json.loads(line) for line in path.read_text().splitlines()]
        assert len(rows) == 10  # the sink keeps everything
        assert rows[0]["stage"] == "slots" and rows[0]["kind"] == "gauge"
        assert "t_rel" in rows[0]

    def test_subscribe_replays_buffered_events(self):
        log = EventLog()
        log.gauge("slots", 3, pool="p")
        seen = []
        log.subscribe(seen.append, replay=True)
        log.gauge("slots", 4, pool="p")
        assert [e.value for e in seen] == [3.0, 4.0]


def _task(tid, stage, t, pool="sim", method="work", **info):
    return Event(t=t, kind="task", stage=stage, task_id=tid,
                 method=method, topic="default", pool=pool, info=info)


class TestMetricsAggregation:
    def test_synthetic_trace_aggregation(self):
        agg = MetricsAggregator()
        # Two tasks on pool sim: compute 1.0s and 3.0s; one on ml: 2.0s.
        trace = []
        for tid, pool, t0, dur in (("a", "sim", 0.0, 1.0),
                                   ("b", "sim", 0.5, 3.0),
                                   ("c", "ml", 1.0, 2.0)):
            trace += [
                _task(tid, "submitted", t0, pool=pool),
                _task(tid, "queued", t0 + 0.01, pool=pool),
                _task(tid, "picked_up", t0 + 0.02, pool=pool),
                _task(tid, "dispatched", t0 + 0.1, pool=pool),
                _task(tid, "running", t0 + 0.2, pool=pool),
                _task(tid, "completed", t0 + 0.2 + dur, pool=pool),
                _task(tid, "result_received", t0 + 0.3 + dur, pool=pool),
            ]
        for ev in sorted(trace, key=lambda e: e.t):
            agg.observe(ev)

        pools = agg.pool_stats()
        assert pools["sim"].completed == 2
        assert pools["ml"].completed == 1
        assert pools["sim"].busy_seconds == pytest.approx(4.0)
        assert pools["ml"].busy_seconds == pytest.approx(2.0)
        assert pools["sim"].backlog == 0 and pools["sim"].running == 0

        methods = agg.method_stats()
        assert methods["work"]["count"] == 3
        assert methods["work"]["mean_s"] == pytest.approx(2.0)

        over = agg.overhead()
        assert over["queue"]["mean_s"] == pytest.approx(0.1)
        assert over["dispatch"]["mean_s"] == pytest.approx(0.1)
        assert over["compute"]["mean_s"] == pytest.approx(2.0)
        assert over["result"]["mean_s"] == pytest.approx(0.1)

        # makespan: first submit (t=0.0) to last result (b at 0.5+0.3+3.0)
        assert agg.makespan() == pytest.approx(3.8)
        util = agg.utilization(slots_by_pool={"sim": 2, "ml": 2})
        assert util["sim"] == pytest.approx(4.0 / (2 * 3.8))
        assert util["total"] == pytest.approx(6.0 / (4 * 3.8))

    def test_backlog_tracks_submitted_not_running(self):
        agg = MetricsAggregator()
        agg.observe(_task("a", "submitted", 0.0))
        agg.observe(_task("b", "submitted", 0.1))
        assert agg.backlog("sim") == 2
        agg.observe(_task("a", "running", 0.2, info={}))
        assert agg.backlog("sim") == 1

    def test_speculative_twin_not_double_counted(self):
        agg = MetricsAggregator()
        agg.observe(_task("a", "submitted", 0.0))
        agg.observe(_task("a", "running", 1.0, worker_id=0))
        agg.observe(_task("a", "speculated", 5.0))
        agg.observe(_task("a", "running", 5.1, worker_id=1))      # twin
        agg.observe(_task("a", "completed", 6.1, worker_id=1))    # twin wins
        agg.observe(_task("a", "result_received", 6.2))
        agg.observe(_task("a", "decision_made", 6.3))
        agg.observe(_task("a", "completed", 7.0, worker_id=0))    # late loser
        st = agg.pool_stats()["sim"]
        assert st.completed == 1           # one task, not one per copy
        assert st.running == 0             # both copies retired
        # busy time covers BOTH copies' real worker occupancy
        assert st.busy_seconds == pytest.approx((6.1 - 5.1) + (7.0 - 1.0))
        assert agg.method_stats()["work"]["count"] == 1
        # transient per-task state fully dropped (no leak from the
        # decision_made / late-loser events arriving after result_received)
        assert agg._marks == {} and agg._run_start == {}

    def test_capacity_integral_from_slot_gauges(self):
        agg = MetricsAggregator()
        agg.observe(Event(t=0.0, kind="gauge", stage="slots", pool="sim", value=4))
        agg.observe(Event(t=10.0, kind="gauge", stage="slots", pool="sim", value=2))
        agg.observe(_task("x", "submitted", 20.0))
        # 4 slots for 10 s + 2 slots for 10 s = 60 slot-seconds
        assert agg.capacity_slot_seconds("sim", until=20.0) == pytest.approx(60.0)


class TestReallocator:
    def test_greedy_shifts_toward_backlogged_pool(self):
        rec = ResourceCounter(4, pools=["a", "b"])  # all 4 slots in "a"
        backlog = {"a": 0, "b": 5}
        r = AdaptiveReallocator(rec, pools=["a", "b"],
                                policy=GreedyBacklogPolicy(),
                                backlog=lambda p: backlog[p])
        assert r.step() is True
        assert rec.allocation("b") == 4  # all idle slots migrate at once
        assert rec.allocation("a") == 0
        assert r.step() is False  # nothing left to move

    def test_min_slots_floor_respected(self):
        rec = ResourceCounter(4, pools=["a", "b"])
        r = AdaptiveReallocator(rec, pools=["a", "b"],
                                policy=GreedyBacklogPolicy(),
                                backlog=lambda p: 9 if p == "b" else 0,
                                min_slots={"a": 3})
        r.step()
        assert rec.allocation("a") == 3
        assert rec.allocation("b") == 1

    def test_busy_slots_never_move(self):
        rec = ResourceCounter(2, pools=["a", "b"])
        assert rec.acquire("a", 2, timeout=1)  # both slots busy
        r = AdaptiveReallocator(rec, pools=["a", "b"],
                                policy=GreedyBacklogPolicy(),
                                backlog=lambda p: 5 if p == "b" else 0,
                                acquire_timeout=0.01)
        assert r.step() is False
        assert rec.allocation("a") == 2

    def test_ema_policy_has_hysteresis(self):
        policy = EMABacklogPolicy(alpha=1.0, hysteresis=1.0)
        views = [PoolView("a", allocation=2, free=1, backlog=0),
                 PoolView("b", allocation=2, free=0, backlog=1)]
        assert policy.decide(views) is None  # gap too small: no thrash
        views[1] = PoolView("b", allocation=2, free=0, backlog=8)
        mv = policy.decide(views)
        assert mv is not None and mv.src == "a" and mv.dst == "b" and mv.n == 1

    def test_resource_counter_allocation_tracking(self):
        rec = ResourceCounter(6, pools=["x", "y"])
        assert rec.allocations() == {"x": 6, "y": 0}
        rec.reallocate("x", "y", 2)
        assert rec.allocations() == {"x": 4, "y": 2}
        assert rec.acquire("y", 1, timeout=1)
        assert rec.allocation("y") == 2  # acquire does not change allocation
        rec.grow("y", 3)
        assert rec.allocations() == {"x": 4, "y": 5}
        assert rec.shrink("x", 4, timeout=1)
        assert rec.allocations() == {"x": 0, "y": 5}


class TestAdaptiveBeatsStatic:
    """The acceptance comparison: on the imbalanced two-pool workload the
    AdaptiveReallocator must reach at least the static split's
    utilization, with a complete lifecycle trace for every task."""

    @pytest.fixture(scope="class")
    def runs(self):
        static, _, _ = run_two_pool(
            n_slots=6, n_sim=30, n_ml=5, task_s=0.03, adaptive=False)
        adaptive, log, thinker = run_two_pool(
            n_slots=6, n_sim=30, n_ml=5, task_s=0.03, adaptive=True)
        return static, adaptive, log, thinker

    def test_all_tasks_complete(self, runs):
        static, adaptive, _, thinker = runs
        assert static["pools"]["sim"]["completed"] == 30
        assert static["pools"]["ml"]["completed"] == 5
        assert adaptive["pools"]["sim"]["completed"] == 30
        assert adaptive["pools"]["ml"]["completed"] == 5
        assert len(thinker.results) == 35

    def test_adaptive_utilization_at_least_static(self, runs):
        static, adaptive, _, _ = runs
        # The static split strands the ml slots once ml work drains
        # (~half the slots idle for most of the run), so adaptive wins by
        # a wide margin — the >= assertion is robust to scheduling noise.
        assert adaptive["utilization"]["total"] >= static["utilization"]["total"]

    def test_reallocation_happened(self, runs):
        _, adaptive, _, thinker = runs
        assert thinker.reallocator is not None
        assert len(thinker.reallocator.moves) >= 1
        assert adaptive["reallocations"]  # recorded in the event log too
        assert all(m["dst"] == "sim" for m in adaptive["reallocations"])

    def test_event_log_has_every_lifecycle_stage(self, runs):
        _, _, log, _ = runs
        assert lifecycle_gaps(log) == {}
        assert lifecycle_order_violations(log) == []
        by_task = log.by_task()
        assert len(by_task) == 35
        for tid, evs in by_task.items():
            stages = {e.stage for e in evs}
            missing = [s for s in REQUIRED if s not in stages]
            assert not missing, f"{tid} missing {missing}"


class TestReportRendering:
    def test_build_and_render(self):
        log = EventLog()
        _run_tasks(log, n_tasks=6)
        report = build_report(log, total_slots=4)
        assert report["lifecycle"]["complete"]
        assert report["stage_counts"]["completed"] == 6
        assert 0 < report["utilization"]["total"] <= 1.0
        text = render_text(report)
        assert "lifecycle:       complete & ordered" in text
        assert "overhead breakdown" in text
