"""Training + serving substrate tests: optimizers, accumulation equivalence,
checkpointing, gradient compression (hypothesis properties), data pipeline
determinism, serving engine continuous batching."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: pip install -e .[test]
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.configs import smoke_config
from repro.models import build_model
from repro.serve import Request, ServingEngine
from repro.train import (
    CheckpointManager,
    CompressedSync,
    DataConfig,
    OptimizerConfig,
    PrefetchLoader,
    SyntheticLM,
    compress_tree,
    decompress_tree,
    init_train_state,
    make_train_step,
    payload_bytes,
    quantize_int8,
    dequantize_int8,
)

HSET = dict(max_examples=10, deadline=None,
            suppress_health_check=[HealthCheck.too_slow])


@pytest.fixture(scope="module")
def small_model():
    cfg = smoke_config("yi-6b").with_(dtype="float32")
    return cfg, build_model(cfg)


class TestOptimizers:
    @pytest.mark.parametrize("name,state_dtype", [
        ("adamw", "float32"), ("adamw", "bfloat16"),
        ("adafactor", "float32"), ("adafactor", "bfloat16"),
    ])
    def test_converges(self, small_model, name, state_dtype):
        cfg, m = small_model
        oc = OptimizerConfig(name=name, lr=3e-3, warmup_steps=2, total_steps=50,
                             state_dtype=state_dtype)
        params, opt = init_train_state(m, oc, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(m, oc))
        data = SyntheticLM(cfg, seq_len=16, batch=8)
        first = last = None
        for s in range(12):
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(s).items()}
            params, opt, metrics = step(params, opt, batch)
            if s == 0:
                first = float(metrics["loss"])
        last = float(metrics["loss"])
        assert np.isfinite(last) and last < first

    def test_lr_schedule_shape(self):
        from repro.train.optimizer import lr_at
        oc = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
        lrs = [float(lr_at(oc, jnp.asarray(s))) for s in [0, 5, 10, 55, 100]]
        assert lrs[0] < lrs[1] < lrs[2]          # warmup
        assert lrs[2] == pytest.approx(1.0)      # peak
        assert lrs[4] == pytest.approx(0.1, abs=0.02)   # floor

    def test_grad_accum_equivalent(self, small_model):
        """grad_accum=1 vs 4 produce (nearly) identical updates."""
        cfg, _ = small_model
        data = SyntheticLM(cfg, seq_len=16, batch=8)
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
        outs = {}
        for accum in (1, 4):
            c = cfg.with_(grad_accum=accum)
            m = build_model(c)
            oc = OptimizerConfig(lr=1e-3, warmup_steps=0, total_steps=10)
            params, opt = init_train_state(m, oc, jax.random.PRNGKey(0))
            step = jax.jit(make_train_step(m, oc))
            new_p, _, metrics = step(params, opt, batch)
            outs[accum] = (new_p, float(metrics["loss"]))
        p1 = jax.tree_util.tree_leaves(outs[1][0])
        p4 = jax.tree_util.tree_leaves(outs[4][0])
        max_err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(p1, p4))
        assert max_err < 1e-4
        assert outs[1][1] == pytest.approx(outs[4][1], rel=1e-4)


class TestCheckpoint:
    def test_atomic_and_gc(self, tmp_path, small_model):
        cfg, m = small_model
        params = m.init(jax.random.PRNGKey(0))
        ck = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            ck.save(s, {"params": params}, extra={"s": s})
        assert ck.all_steps() == [3, 4]           # gc keeps last 2
        restored, extra = ck.restore(4, {"params": params})
        assert extra == {"s": 4}
        for a, b in zip(jax.tree_util.tree_leaves(restored),
                        jax.tree_util.tree_leaves({"params": params})):
            assert np.allclose(a, b)

    def test_async_overlap(self, tmp_path, small_model):
        cfg, m = small_model
        params = m.init(jax.random.PRNGKey(0))
        ck = CheckpointManager(str(tmp_path))
        t0 = time.monotonic()
        ck.save_async(1, {"params": params})
        submit_time = time.monotonic() - t0
        ck.wait()
        assert ck.latest_step() == 1
        assert submit_time < 5.0  # snapshot is cheap; write happens in background

    def test_crash_leaves_no_partial(self, tmp_path, small_model):
        """A .tmp dir from a crashed writer must not be visible as a step."""
        cfg, m = small_model
        ck = CheckpointManager(str(tmp_path))
        os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp"))
        assert ck.latest_step() is None


class TestGradCompression:
    @given(st.integers(1, 5), st.floats(1e-4, 10.0))
    @settings(**HSET)
    def test_quantize_bounded_error(self, rows, scale):
        rng = np.random.default_rng(rows)
        x = jnp.asarray(rng.standard_normal((rows, 64)) * scale)
        q, s = quantize_int8(x)
        deq = dequantize_int8(q, s)
        # per-row error bounded by scale/2 = max|x|/254
        bound = np.asarray(jnp.max(jnp.abs(x), axis=-1, keepdims=True)) / 254 + 1e-9
        assert (np.abs(np.asarray(deq - x)) <= bound * 1.01).all()

    def test_error_feedback_unbiased_over_time(self):
        """Sum of dequantized payloads + final error == sum of raw grads."""
        rng = np.random.default_rng(0)
        err = None
        total_raw = np.zeros((8, 16))
        total_sent = np.zeros((8, 16))
        for step in range(20):
            g = {"w": jnp.asarray(rng.standard_normal((8, 16)) * 1e-3)}
            payload, err = compress_tree(g, err)
            total_raw += np.asarray(g["w"])
            total_sent += np.asarray(decompress_tree(payload)["w"])
        residual = np.asarray(jax.tree_util.tree_leaves(err)[0])
        assert np.allclose(total_sent + residual, total_raw, atol=1e-5)

    def test_sync_compression_ratio(self):
        g = {"w": jnp.asarray(np.random.default_rng(1).standard_normal((64, 128)))}
        sync = CompressedSync(n_pods=2)
        sync.contribute(0, g)
        sync.contribute(1, g)
        avg = sync.reduce()
        assert sync.bytes_uncompressed / sync.bytes_sent > 3.5
        rel = float(jnp.max(jnp.abs(avg["w"] - g["w"])) / jnp.max(jnp.abs(g["w"])))
        assert rel < 2e-2


class TestData:
    def test_deterministic_across_restart(self):
        cfg = smoke_config("gemma-2b")
        d1 = SyntheticLM(cfg, 16, 4)
        d2 = SyntheticLM(cfg, 16, 4)
        b1, b2 = d1.batch_at(7), d2.batch_at(7)
        assert np.array_equal(b1["tokens"], b2["tokens"])

    def test_labels_are_shifted_tokens(self):
        cfg = smoke_config("gemma-2b")
        b = SyntheticLM(cfg, 16, 2).batch_at(0)
        assert np.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_learnable_structure(self):
        """Markov component: following token is predictable > chance."""
        cfg = smoke_config("gemma-2b")
        d = SyntheticLM(cfg, 256, 4)
        b = d.batch_at(0)
        pred = d.next_pref[b["tokens"]]
        hit = (pred == b["labels"]).mean()
        assert hit > 0.5

    def test_prefetch_matches_direct(self):
        cfg = smoke_config("gemma-2b")
        src = SyntheticLM(cfg, 8, 2)
        loader = PrefetchLoader(src, start_step=0)
        step, batch = next(loader)
        assert step == 0
        assert np.array_equal(batch["tokens"], src.batch_at(0)["tokens"])
        loader.close()


class TestServingEngine:
    def test_continuous_batching_drains(self):
        cfg = smoke_config("gemma-2b").with_(dtype="float32")
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        eng = ServingEngine(m, params, n_slots=2, max_len=48)
        for i in range(5):
            eng.submit(Request(request_id=i, prompt=np.arange(1, 4, dtype=np.int32),
                               max_new_tokens=4))
        stats = eng.run_until_drained()
        assert stats.requests_finished == 5
        assert stats.tokens_generated == 20

    def test_steering_hook_cancels(self):
        cfg = smoke_config("gemma-2b").with_(dtype="float32")
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        eng = ServingEngine(m, params, n_slots=2, max_len=48,
                            on_token=lambda req, tok: len(req.generated) >= 1)
        eng.submit(Request(request_id=0, prompt=np.asarray([1, 2], np.int32),
                           max_new_tokens=10))
        stats = eng.run_until_drained()
        assert stats.requests_cancelled == 1
        assert stats.tokens_generated == 1

    def test_prefix_isolation_between_slots(self):
        """Two different prompts decoded concurrently give the same tokens
        as decoded alone (slot isolation)."""
        cfg = smoke_config("gemma-2b").with_(dtype="float32")
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))

        def gen(prompts):
            eng = ServingEngine(m, params, n_slots=len(prompts), max_len=48)
            for i, p in enumerate(prompts):
                eng.submit(Request(request_id=i, prompt=p, max_new_tokens=5))
            reqs = {}
            eng.on_finish = lambda r: reqs.setdefault(r.request_id, r.generated)
            eng.run_until_drained()
            return reqs

        p0 = np.asarray([5, 6, 7], np.int32)
        p1 = np.asarray([9, 10], np.int32)
        together = gen([p0, p1])
        alone0 = gen([p0])
        alone1 = gen([p1])
        assert together[0] == alone0[0]
        assert together[1] == alone1[0 if 0 in alone1 else 1] or together[1] == list(alone1.values())[0]
