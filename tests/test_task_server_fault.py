"""Integration tests: TaskServer reliability machinery (the 1000-node story).

Covers: retries on injected node failures, heartbeat-based worker
replacement, straggler speculation, multi-pool routing, task timeouts via
wall-clock monitoring, elastic pool resize, and campaign checkpoint/resume.
"""

import os
import threading
import time

import numpy as np
import pytest

from repro.core import (
    BaseThinker,
    Campaign,
    ConstantInflightThinker,
    FailureInjector,
    FailureKind,
    LocalColmenaQueues,
    ResourceRequest,
    RetryPolicy,
    StragglerPolicy,
    TaskServer,
    WorkerPool,
    agent,
    result_processor,
    stateful_task,
)


def sleepy(x, dt=0.01):
    time.sleep(dt)
    return x


class TestTaskServer:
    def test_basic_dispatch(self):
        q = LocalColmenaQueues()
        server = TaskServer(q, {"f": lambda x: x * 2}, n_workers=2).start()
        q.send_inputs(21, method="f")
        r = q.get_result(timeout=5)
        assert r.success and r.value == 42
        server.stop()

    def test_unknown_method_fails_cleanly(self):
        q = LocalColmenaQueues()
        server = TaskServer(q, {}, n_workers=1).start()
        q.send_inputs(1, method="nope")
        r = q.get_result(timeout=5)
        assert not r.success and "unknown method" in r.failure_info
        server.stop()

    def test_retries_survive_node_failures(self):
        q = LocalColmenaQueues()
        inj = FailureInjector(task_failure_rate=0.3, seed=42)
        server = TaskServer(
            q, {"f": sleepy}, n_workers=4, injector=inj,
            retry=RetryPolicy(max_retries=10),
        ).start()
        work = [((i,), {}) for i in range(25)]
        thinker = ConstantInflightThinker(q, work, method="f", n_parallel=4)
        thinker.run(timeout=30)
        assert len(thinker.results) == 25
        assert all(r.success for r in thinker.results)
        assert server.metrics.tasks_retried > 0
        assert server.metrics.workers_replaced > 0
        server.stop()

    def test_heartbeat_failover(self):
        q = LocalColmenaQueues()
        pool = WorkerPool("default", 2)
        server = TaskServer(
            q, {"slow": lambda: sleepy(1, 0.6)}, pools={"default": pool},
            heartbeat_timeout_s=0.2,
        ).start()
        q.send_inputs(method="slow")
        time.sleep(0.15)
        # kill the worker running the task -> heartbeat monitor fails over
        busy = [w for w in pool.worker_states() if w.busy]
        assert busy
        pool.kill_worker(busy[0].worker_id)
        r = q.get_result(timeout=10)
        assert r.success  # retried on a replacement worker
        server.stop()

    def test_straggler_speculation(self):
        q = LocalColmenaQueues()
        inj = FailureInjector(slow_workers={0: 1.5})   # worker 0 is a straggler
        server = TaskServer(
            q, {"f": sleepy}, n_workers=2, injector=inj,
            straggler=StragglerPolicy(enabled=True, factor=3.0, min_history=3,
                                      check_interval_s=0.05),
        ).start()
        for i in range(8):
            q.send_inputs(i, method="f")
        got = [q.get_result(timeout=20) for _ in range(8)]
        assert all(r.success for r in got)
        assert server.metrics.speculative_launched >= 1
        server.stop()

    def test_multi_pool_routing(self):
        q = LocalColmenaQueues(topics=["sim", "ml"])
        pools = {
            "sim": WorkerPool("sim", 2),
            "ml": WorkerPool("ml", 1),
            "default": WorkerPool("default", 1),
        }
        hits = {"sim": 0, "ml": 0}

        @stateful_task
        def tag(x, registry=None):
            registry.setdefault("n", 0)
            registry["n"] += 1
            return threading.current_thread().name

        server = TaskServer(q, {"tag": tag}, pools=pools).start()
        q.send_inputs(1, method="tag", topic="sim", resources=ResourceRequest(pool="sim"))
        q.send_inputs(2, method="tag", topic="ml", resources=ResourceRequest(pool="ml"))
        r_sim = q.get_result(topic="sim", timeout=5)
        r_ml = q.get_result(topic="ml", timeout=5)
        assert "sim-worker" in r_sim.value
        assert "ml-worker" in r_ml.value
        server.stop()

    def test_stateful_worker_registry_persists(self):
        q = LocalColmenaQueues()

        @stateful_task
        def counter(registry=None):
            registry["n"] = registry.get("n", 0) + 1
            return registry["n"]

        server = TaskServer(q, {"counter": counter}, n_workers=1).start()
        for _ in range(3):
            q.send_inputs(method="counter")
        vals = sorted(q.get_result(timeout=5).value for _ in range(3))
        assert vals == [1, 2, 3]   # cache survives across invocations
        server.stop()

    def test_timeout_fails_over_hung_task(self):
        q = LocalColmenaQueues()
        hang_once = threading.Event()
        hang_once.set()

        def f(x):
            if hang_once.is_set():
                hang_once.clear()
                time.sleep(30)       # a hung 'first attempt'
            return x

        server = TaskServer(
            q, {"f": f}, n_workers=2,
            straggler=StragglerPolicy(enabled=False, check_interval_s=0.05),
        ).start()
        q.send_inputs(7, method="f",
                      resources=ResourceRequest(timeout_s=0.3))
        r = q.get_result(timeout=10)
        assert r.success and r.value == 7   # retried after the timeout
        assert server.metrics.tasks_retried >= 1
        # the hung attempt's eventual completion must not double-send
        assert q.get_result(timeout=0.5) is None
        server.stop()

    def test_timeout_exhausts_retries(self):
        q = LocalColmenaQueues()
        server = TaskServer(
            q, {"f": lambda: sleepy(0, 10)}, n_workers=1,
            retry=RetryPolicy(max_retries=0),
            straggler=StragglerPolicy(enabled=False, check_interval_s=0.05),
        ).start()
        q.send_inputs(method="f", resources=ResourceRequest(timeout_s=0.2))
        r = q.get_result(timeout=10)
        assert not r.success and r.failure is FailureKind.TIMEOUT
        server.stop()

    def test_speculative_loser_not_delivered_twice(self):
        q = LocalColmenaQueues()
        inj = FailureInjector(slow_workers={0: 1.0})   # worker 0 straggles
        server = TaskServer(
            q, {"f": sleepy}, n_workers=2, injector=inj,
            straggler=StragglerPolicy(enabled=True, factor=3.0, min_history=3,
                                      check_interval_s=0.05),
        ).start()
        n = 8
        for i in range(n):
            q.send_inputs(i, method="f")
        got = [q.get_result(timeout=20) for _ in range(n)]
        assert all(r.success for r in got)
        assert len({r.task_id for r in got}) == n
        assert server.metrics.speculative_launched >= 1
        # exactly one result per task: the twin that lost the race is dropped
        assert q.get_result(timeout=1.2) is None
        server.stop()

    def test_elastic_resize(self):
        pool = WorkerPool("default", 2)
        assert pool.n_workers == 2
        pool.add_workers(3)
        assert pool.n_workers == 5
        pool.remove_workers(4)
        deadline = time.time() + 2
        while pool.n_workers > 1 and time.time() < deadline:
            time.sleep(0.02)
        assert pool.n_workers == 1
        pool.shutdown()


class TestCampaign:
    def test_checkpoint_resume(self, tmp_path):
        q = LocalColmenaQueues()

        class T(BaseThinker):
            def __init__(self):
                super().__init__(q)
                self.progress = 0

            def get_state(self):
                return {"progress": self.progress}

            def set_state(self, s):
                self.progress = s["progress"]

            @agent
            def main(self):
                for _ in range(3):
                    self.progress += 1
                    time.sleep(0.01)

        server = TaskServer(q, {"f": lambda: 1}, n_workers=1)
        camp = Campaign(T(), server, state_dir=str(tmp_path), checkpoint_interval_s=0.05)
        report = camp.run(timeout=5)
        assert report.completed and report.checkpoints_written >= 1

        # resume restores thinker state
        t2 = T()
        server2 = TaskServer(LocalColmenaQueues(), {"f": lambda: 1}, n_workers=1)
        camp2 = Campaign(t2, server2, state_dir=str(tmp_path))
        assert camp2.try_resume()
        assert t2.progress == 3
        server.stop()
        server2.stop()
