"""Integration tests: TaskServer reliability machinery (the 1000-node story).

Covers: retries on injected node failures, heartbeat-based worker
replacement, straggler speculation, multi-pool routing, task timeouts via
wall-clock monitoring, elastic pool resize, and campaign checkpoint/resume.
"""

import logging
import os
import threading
import time

import numpy as np
import pytest

from repro.core import (
    BaseThinker,
    Campaign,
    ConstantInflightThinker,
    FailureInjector,
    FailureKind,
    LocalColmenaQueues,
    ResourceRequest,
    RetryPolicy,
    StragglerPolicy,
    TaskServer,
    WorkerPool,
    agent,
    result_processor,
    stateful_task,
)


def sleepy(x, dt=0.01):
    time.sleep(dt)
    return x


class TestTaskServer:
    def test_basic_dispatch(self):
        q = LocalColmenaQueues()
        server = TaskServer(q, {"f": lambda x: x * 2}, n_workers=2).start()
        q.send_inputs(21, method="f")
        r = q.get_result(timeout=5)
        assert r.success and r.value == 42
        server.stop()

    def test_unknown_method_fails_cleanly(self):
        q = LocalColmenaQueues()
        server = TaskServer(q, {}, n_workers=1).start()
        q.send_inputs(1, method="nope")
        r = q.get_result(timeout=5)
        assert not r.success and "unknown method" in r.failure_info
        server.stop()

    def test_retries_survive_node_failures(self):
        q = LocalColmenaQueues()
        inj = FailureInjector(task_failure_rate=0.3, seed=42)
        server = TaskServer(
            q, {"f": sleepy}, n_workers=4, injector=inj,
            retry=RetryPolicy(max_retries=10),
        ).start()
        work = [((i,), {}) for i in range(25)]
        thinker = ConstantInflightThinker(q, work, method="f", n_parallel=4)
        thinker.run(timeout=30)
        assert len(thinker.results) == 25
        assert all(r.success for r in thinker.results)
        assert server.metrics.tasks_retried > 0
        assert server.metrics.workers_replaced > 0
        server.stop()

    def test_heartbeat_failover(self):
        q = LocalColmenaQueues()
        pool = WorkerPool("default", 2)
        server = TaskServer(
            q, {"slow": lambda: sleepy(1, 0.6)}, pools={"default": pool},
            heartbeat_timeout_s=0.2,
        ).start()
        q.send_inputs(method="slow")
        time.sleep(0.15)
        # kill the worker running the task -> heartbeat monitor fails over
        busy = [w for w in pool.worker_states() if w.busy]
        assert busy
        pool.kill_worker(busy[0].worker_id)
        r = q.get_result(timeout=10)
        assert r.success  # retried on a replacement worker
        server.stop()

    def test_straggler_speculation(self):
        q = LocalColmenaQueues()
        inj = FailureInjector(slow_workers={0: 1.5})   # worker 0 is a straggler
        server = TaskServer(
            q, {"f": sleepy}, n_workers=2, injector=inj,
            straggler=StragglerPolicy(enabled=True, factor=3.0, min_history=3,
                                      check_interval_s=0.05),
        ).start()
        for i in range(8):
            q.send_inputs(i, method="f")
        got = [q.get_result(timeout=20) for _ in range(8)]
        assert all(r.success for r in got)
        assert server.metrics.speculative_launched >= 1
        server.stop()

    def test_multi_pool_routing(self):
        q = LocalColmenaQueues(topics=["sim", "ml"])
        pools = {
            "sim": WorkerPool("sim", 2),
            "ml": WorkerPool("ml", 1),
            "default": WorkerPool("default", 1),
        }
        hits = {"sim": 0, "ml": 0}

        @stateful_task
        def tag(x, registry=None):
            registry.setdefault("n", 0)
            registry["n"] += 1
            return threading.current_thread().name

        server = TaskServer(q, {"tag": tag}, pools=pools).start()
        q.send_inputs(1, method="tag", topic="sim", resources=ResourceRequest(pool="sim"))
        q.send_inputs(2, method="tag", topic="ml", resources=ResourceRequest(pool="ml"))
        r_sim = q.get_result(topic="sim", timeout=5)
        r_ml = q.get_result(topic="ml", timeout=5)
        assert "sim-worker" in r_sim.value
        assert "ml-worker" in r_ml.value
        server.stop()

    def test_stateful_worker_registry_persists(self):
        q = LocalColmenaQueues()

        @stateful_task
        def counter(registry=None):
            registry["n"] = registry.get("n", 0) + 1
            return registry["n"]

        server = TaskServer(q, {"counter": counter}, n_workers=1).start()
        for _ in range(3):
            q.send_inputs(method="counter")
        vals = sorted(q.get_result(timeout=5).value for _ in range(3))
        assert vals == [1, 2, 3]   # cache survives across invocations
        server.stop()

    def test_timeout_fails_over_hung_task(self):
        q = LocalColmenaQueues()
        hang_once = threading.Event()
        hang_once.set()

        def f(x):
            if hang_once.is_set():
                hang_once.clear()
                time.sleep(30)       # a hung 'first attempt'
            return x

        server = TaskServer(
            q, {"f": f}, n_workers=2,
            straggler=StragglerPolicy(enabled=False, check_interval_s=0.05),
        ).start()
        q.send_inputs(7, method="f",
                      resources=ResourceRequest(timeout_s=0.3))
        r = q.get_result(timeout=10)
        assert r.success and r.value == 7   # retried after the timeout
        assert server.metrics.tasks_retried >= 1
        # the hung attempt's eventual completion must not double-send
        assert q.get_result(timeout=0.5) is None
        server.stop()

    def test_timeout_exhausts_retries(self):
        q = LocalColmenaQueues()
        server = TaskServer(
            q, {"f": lambda: sleepy(0, 10)}, n_workers=1,
            retry=RetryPolicy(max_retries=0),
            straggler=StragglerPolicy(enabled=False, check_interval_s=0.05),
        ).start()
        q.send_inputs(method="f", resources=ResourceRequest(timeout_s=0.2))
        r = q.get_result(timeout=10)
        assert not r.success and r.failure is FailureKind.TIMEOUT
        server.stop()

    def test_speculative_loser_not_delivered_twice(self):
        q = LocalColmenaQueues()
        inj = FailureInjector(slow_workers={0: 1.0})   # worker 0 straggles
        server = TaskServer(
            q, {"f": sleepy}, n_workers=2, injector=inj,
            straggler=StragglerPolicy(enabled=True, factor=3.0, min_history=3,
                                      check_interval_s=0.05),
        ).start()
        n = 8
        for i in range(n):
            q.send_inputs(i, method="f")
        got = [q.get_result(timeout=20) for _ in range(n)]
        assert all(r.success for r in got)
        assert len({r.task_id for r in got}) == n
        assert server.metrics.speculative_launched >= 1
        # exactly one result per task: the twin that lost the race is dropped
        assert q.get_result(timeout=1.2) is None
        server.stop()

    def test_retry_storm_not_serialized_by_backoff(self):
        """N concurrent failing tasks must not serialize on retry backoff:
        retries go through the deadline heap, the completion path never
        sleeps. With the old ``time.sleep(backoff)`` in ``_complete`` six
        0.5 s backoffs serialized across two worker threads (>= 1.5 s);
        the heap schedules them all concurrently (~one backoff total)."""
        q = LocalColmenaQueues()
        failed_once = set()
        lock = threading.Lock()

        def flaky(x):
            with lock:
                if x not in failed_once:
                    failed_once.add(x)
                    raise RuntimeError(f"first attempt of {x} fails")
            return x

        server = TaskServer(
            q, {"flaky": flaky}, n_workers=2,
            retry=RetryPolicy(max_retries=2, backoff_s=0.5,
                              retry_on=(FailureKind.EXCEPTION,)),
            straggler=StragglerPolicy(enabled=False, check_interval_s=0.05),
        ).start()
        n = 6
        t0 = time.monotonic()
        for i in range(n):
            q.send_inputs(i, method="flaky")
        got = [q.get_result(timeout=10) for _ in range(n)]
        wall = time.monotonic() - t0
        assert all(r is not None and r.success for r in got)
        assert sorted(r.value for r in got) == list(range(n))
        assert server.metrics.tasks_retried == n
        # one shared backoff window, not one per task
        assert wall < 1.4, f"retries serialized: {wall:.2f}s for {n} x 0.5s backoffs"
        assert server.pending_retries() == 0
        server.stop()

    def test_backoff_window_does_not_stall_other_completions(self):
        """While failed tasks sit in their backoff window, unrelated
        instant tasks must keep completing (the completion path used to
        sleep out the backoff on the worker thread)."""
        q = LocalColmenaQueues()

        def boom():
            raise RuntimeError("always fails")

        server = TaskServer(
            q, {"boom": boom, "instant": lambda x: x}, n_workers=2,
            retry=RetryPolicy(max_retries=3, backoff_s=1.0,
                              retry_on=(FailureKind.EXCEPTION,)),
            straggler=StragglerPolicy(enabled=False, check_interval_s=0.05),
        ).start()
        for _ in range(4):
            q.send_inputs(method="boom")
        time.sleep(0.1)  # let the failures land in the retry heap
        t0 = time.monotonic()
        for i in range(4):
            q.send_inputs(i, method="instant")
        got = [q.get_result(timeout=5) for _ in range(4)]
        wall = time.monotonic() - t0
        assert all(r is not None and r.success for r in got)
        assert wall < 0.8, f"instant tasks stalled {wall:.2f}s behind retry backoffs"
        assert server.pending_retries() >= 1   # the boom retries are still queued
        server.stop()

    def test_timeout_vs_late_result_race(self):
        """A timed-out task whose original attempt finishes *after* the
        failover retry must be delivered exactly once: the late original
        is dropped (its inflight entry is gone), the retry's result is
        the one the client sees."""
        q = LocalColmenaQueues()
        slow_once = threading.Event()
        slow_once.set()

        def f(x):
            if slow_once.is_set():
                slow_once.clear()
                time.sleep(0.6)      # first attempt: slow enough to time out
            return x

        server = TaskServer(
            q, {"f": f}, n_workers=2,
            retry=RetryPolicy(max_retries=2, backoff_s=0.1),
            straggler=StragglerPolicy(enabled=False, check_interval_s=0.05),
        ).start()
        q.send_inputs(11, method="f", resources=ResourceRequest(timeout_s=0.2))
        r = q.get_result(timeout=10)
        assert r is not None and r.success and r.value == 11
        assert server.metrics.tasks_retried >= 1
        # the original attempt wakes at ~0.6s and completes; its delivery
        # must be suppressed — exactly one result ever reaches the client
        assert q.get_result(timeout=1.0) is None
        server.stop()

    def test_stop_returns_promptly(self):
        """``stop()`` must not wait out the monitor poll interval (the
        old ``_monitor_loop`` slept a full ``check_interval_s`` before
        rechecking) nor the retry heap's next deadline."""
        q = LocalColmenaQueues()
        server = TaskServer(
            q, {"boom": lambda: (_ for _ in ()).throw(RuntimeError("x"))},
            n_workers=1,
            retry=RetryPolicy(max_retries=1, backoff_s=30.0,
                              retry_on=(FailureKind.EXCEPTION,)),
            straggler=StragglerPolicy(check_interval_s=5.0),
        ).start()
        q.send_inputs(method="boom")
        deadline = time.monotonic() + 2
        while server.pending_retries() == 0 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert server.pending_retries() == 1   # a retry parked 30s out
        t0 = time.monotonic()
        server.stop()
        assert time.monotonic() - t0 < 1.0, "stop() waited out a poll interval"

    def test_elastic_resize(self):
        pool = WorkerPool("default", 2)
        assert pool.n_workers == 2
        pool.add_workers(3)
        assert pool.n_workers == 5
        pool.remove_workers(4)
        deadline = time.time() + 2
        while pool.n_workers > 1 and time.time() < deadline:
            time.sleep(0.02)
        assert pool.n_workers == 1
        pool.shutdown()


class TestCampaign:
    def test_checkpoint_resume(self, tmp_path):
        q = LocalColmenaQueues()

        class T(BaseThinker):
            def __init__(self):
                super().__init__(q)
                self.progress = 0

            def get_state(self):
                return {"progress": self.progress}

            def set_state(self, s):
                self.progress = s["progress"]

            @agent
            def main(self):
                for _ in range(3):
                    self.progress += 1
                    time.sleep(0.01)

        server = TaskServer(q, {"f": lambda: 1}, n_workers=1)
        camp = Campaign(T(), server, state_dir=str(tmp_path), checkpoint_interval_s=0.05)
        report = camp.run(timeout=5)
        assert report.completed and report.checkpoints_written >= 1

        # resume restores thinker state
        t2 = T()
        server2 = TaskServer(LocalColmenaQueues(), {"f": lambda: 1}, n_workers=1)
        camp2 = Campaign(t2, server2, state_dir=str(tmp_path))
        assert camp2.try_resume()
        assert t2.progress == 3
        server.stop()
        server2.stop()

    def _mk_campaign(self, tmp_path, progress=0):
        q = LocalColmenaQueues()

        class T(BaseThinker):
            def __init__(self):
                super().__init__(q)
                self.progress = progress

            def get_state(self):
                return {"progress": self.progress}

            def set_state(self, s):
                self.progress = s["progress"]

        t = T()
        server = TaskServer(q, {"f": lambda: 1}, n_workers=1)
        return t, Campaign(t, server, state_dir=str(tmp_path))

    def test_corrupt_checkpoint_falls_back(self, tmp_path, caplog):
        """A truncated (torn-write) newest checkpoint logs a warning and
        resume falls back to the previous retained checkpoint instead of
        silently resuming from nothing — or crashing."""
        t, camp = self._mk_campaign(tmp_path)
        for step in range(3):
            t.progress = step + 1
            camp.checkpoint()
        newest = camp.latest_checkpoint()
        with open(newest, "rb+") as f:
            f.truncate(os.path.getsize(newest) // 2)

        t2, camp2 = self._mk_campaign(tmp_path, progress=-1)
        with caplog.at_level(logging.WARNING, logger="repro.campaign"):
            assert camp2.try_resume()
        assert t2.progress == 2               # the step-2 checkpoint, not nothing
        assert camp2.resume_fallbacks == 1
        assert any("corrupt" in rec.message for rec in caplog.records)
        # new checkpoints continue past the survivor, never overwrite history
        assert camp2.checkpoints_written == 2

    def test_bitflipped_checkpoint_detected_by_digest(self, tmp_path):
        """A bit-flip deep in the pickled payload still unpickles the
        envelope — the content digest is what catches it."""
        from repro.chaos import corrupt_file

        t, camp = self._mk_campaign(tmp_path)
        for step in range(2):
            t.progress = step + 1
            camp.checkpoint()
        corrupt_file(camp.latest_checkpoint(), n_bytes=8, offset_frac=0.7)

        t2, camp2 = self._mk_campaign(tmp_path)
        assert camp2.try_resume()
        assert t2.progress == 1
        assert camp2.resume_fallbacks == 1

    def test_all_checkpoints_corrupt_resumes_nothing(self, tmp_path):
        t, camp = self._mk_campaign(tmp_path)
        camp.checkpoint()
        camp.checkpoint()
        for path in camp._checkpoint_candidates():
            with open(path, "wb") as f:
                f.write(b"not a pickle at all")
        _, camp2 = self._mk_campaign(tmp_path)
        assert not camp2.try_resume()
        assert camp2.resume_fallbacks == 2

    def test_retention_keeps_fallback_target(self, tmp_path):
        """``retain`` is clamped to >= 2 so the corrupt-newest fallback
        always has a survivor to land on."""
        t, camp = self._mk_campaign(tmp_path)
        camp.retain = max(2, 0)  # mirrors the constructor clamp
        assert Campaign(t, camp.server, state_dir=str(tmp_path), retain=0).retain == 2
        for step in range(6):
            t.progress = step
            camp.checkpoint()
        assert len(camp._checkpoint_candidates()) >= 2
