"""Sharding-system property tests + a reduced multi-device dry run.

``resolve_pspec`` properties are checked with hypothesis. The actual
multi-device lower+compile is exercised in a SUBPROCESS with
``xla_force_host_platform_device_count=8`` (device count locks at first
jax init, so it can never run in the main pytest process).
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest
pytest.importorskip("hypothesis")  # optional dep: pip install -e .[test]
from hypothesis import given, settings, strategies as st

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.models.layers import axis_rules, resolve_pspec

REPO = os.path.join(os.path.dirname(__file__), "..")


class TestResolvePspec:
    @pytest.fixture(scope="class")
    def mesh(self):
        # a fake mesh object exposing axis_names + shape, no devices needed
        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 4, "model": 2}

        return FakeMesh()

    @given(st.lists(st.sampled_from(
        ["batch", "heads", "ff", "vocab", "embed", None, "kv_heads"]),
        min_size=1, max_size=4),
        st.lists(st.integers(1, 64), min_size=1, max_size=4))
    @settings(max_examples=50, deadline=None)
    def test_divisibility_and_axis_uniqueness(self, logical, dims):
        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 4, "model": 2}

        mesh = FakeMesh()
        n = min(len(logical), len(dims))
        logical, dims = logical[:n], dims[:n]
        cfg = get_config("yi-6b")
        spec = resolve_pspec(logical, dims, mesh, axis_rules(cfg))
        used = []
        for entry, dim in zip(list(spec), dims):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            total = 1
            for a in axes:
                assert a not in used, "mesh axis used twice"
                used.append(a)
                total *= mesh.shape[a]
            assert dim % total == 0, "sharded dim must divide axis size"

    def test_indivisible_falls_back_to_replicated(self):
        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 4, "model": 16}

        cfg = get_config("gemma-2b")   # 8 heads < 16-way model axis
        spec = resolve_pspec(("batch", "seq", "heads", "head_dim"),
                             (32, 128, 8, 256), FakeMesh(), axis_rules(cfg))
        # trailing Nones are stripped by PartitionSpec; only batch shards
        assert list(spec) == ["data"]

    def test_fsdp_rules_shard_weights_over_data(self):
        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}

        cfg = get_config("llama3-405b")
        assert cfg.sharding == "fsdp_tp"
        spec = resolve_pspec(("layers", "embed_w", "heads", "head_dim"),
                             (126, 16384, 128, 128), FakeMesh(), axis_rules(cfg))
        assert list(spec) == [None, "data", "model"]


@pytest.mark.slow
class TestSmallMeshDryRun:
    """Real lower+compile on an 8-device CPU mesh, one subprocess per family."""

    @pytest.mark.parametrize("arch", ["yi-6b", "qwen3-moe-30b-a3b", "rwkv6-1.6b",
                                      "recurrentgemma-2b", "whisper-large-v3",
                                      "internvl2-1b"])
    def test_reduced_dryrun_compiles(self, arch):
        code = textwrap.dedent(f"""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import jax, json
            import jax.numpy as jnp
            from jax.sharding import NamedSharding
            from repro.configs import smoke_config
            from repro.configs.base import ShapeConfig
            from repro.models import mesh_context
            from repro.models.model_api import build_model
            from repro.train.optimizer import OptimizerConfig, init_opt_state
            from repro.train.train_step import make_train_step
            from repro.launch.dryrun import _sds, _opt_pspecs

            mesh = jax.make_mesh((4, 2), ("data", "model"))
            cfg = smoke_config({arch!r}).with_(d_model=64, n_heads=4, head_dim=16,
                                               d_ff=128, grad_accum=2)
            model = build_model(cfg)
            oc = OptimizerConfig()
            shape = ShapeConfig("t", 32, 8, "train")
            with mesh_context(mesh, cfg):
                p_specs = model.pspecs(mesh)
                p_sds = _sds(model.shapes(), p_specs, mesh)
                opt_shapes = jax.eval_shape(lambda p: init_opt_state(p, oc), p_sds)
                o_sds = _sds(opt_shapes, _opt_pspecs(p_specs, opt_shapes, oc), mesh)
                batch_sds = model.input_specs(shape, mesh)
                step = make_train_step(model, oc, mesh)
                compiled = jax.jit(step, donate_argnums=(0, 1)).lower(
                    p_sds, o_sds, batch_sds).compile()
            cost = compiled.cost_analysis()
            if isinstance(cost, list): cost = cost[0]
            print(json.dumps({{"flops": float(cost.get("flops", 0))}}))
        """)
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
        proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                              text=True, env=env, timeout=420)
        assert proc.returncode == 0, proc.stderr[-3000:]
        out = json.loads(proc.stdout.strip().splitlines()[-1])
        assert out["flops"] > 0
