"""Serializable AppSpecs: dict/TOML/JSON round-trips + the launch CLI.

Everything config-file launch depends on: ``spec_to_dict`` /
``spec_from_dict`` inversion (including dotted-path task/thinker
resolution and the error messages bad paths produce), the TOML writer
round-tripping through a real TOML parser, ``$ref``/``$call`` escapes,
``[smoke]`` overrides, resume-through-a-config-file, and the
``python -m repro.app`` CLI end to end.
"""

import json
import os
import subprocess
import sys

import pytest

from repro.app import (
    AppSpec,
    CampaignSpec,
    ColmenaApp,
    FabricSpec,
    ObserveSpec,
    PoolSpec,
    QueueSpec,
    ServerSpec,
    SteeringSpec,
    TaskDef,
    load_spec,
    save_spec,
    spec_from_dict,
    spec_to_dict,
    task,
)
from repro.core import BaseThinker, ResourceCounter, RetryPolicy, agent, result_processor
from repro.core.specfile import SPEC_VERSION, dotted_path, dumps_toml, import_dotted

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SENTINEL = object()


@task(pool="special", timeout_s=2.5)
def special_task(x):
    return x + 1


def plain_task(x):
    return 2 * x


class ConfigThinker(BaseThinker):
    """Checkpointable submit-on-completion thinker for config launches."""

    def __init__(self, queues, target=6, n_parallel=2, sentinel=None):
        super().__init__(queues, ResourceCounter(n_parallel))
        self.target = target
        self.sentinel = sentinel
        self.count = 0

    @agent(startup=True)
    def boot(self):
        for _ in range(self.rec.total_slots):
            self.queues.send_inputs(1, method="double")

    @result_processor()
    def recv(self, result):
        self.count += 1
        if self.count >= self.target:
            self.done.set()
        else:
            self.queues.send_inputs(1, method="double")

    def get_state(self):
        return {"count": self.count}

    def set_state(self, state):
        self.count = state.get("count", 0)


def _full_spec():
    return AppSpec(
        tasks=[TaskDef(fn=plain_task, method="double"), special_task],
        queues=QueueSpec(backend="local", topics=("default", "aux")),
        pools={
            "default": PoolSpec("default", 2, min_size=1, max_size=4),
            "special": 1,
        },
        fabric=FabricSpec(connector="memory", threshold=5000, warm_capacity=16),
        observe=ObserveSpec(capacity=4096, elastic={"interval": 0.02}),
        steering=SteeringSpec(ConfigThinker, dict(target=4, n_parallel=2)),
        server=ServerSpec(retry=RetryPolicy(max_retries=3, backoff_s=0.01)),
    )


class TestDictRoundTrip:
    def test_to_dict_from_dict_fixed_point(self):
        spec = _full_spec()
        d = spec_to_dict(spec)
        spec2 = spec_from_dict(d)
        assert spec_to_dict(spec2) == d

    def test_toml_round_trip_through_real_parser(self):
        try:
            import tomllib
        except ModuleNotFoundError:
            tomllib = pytest.importorskip("tomli")
        d = spec_to_dict(_full_spec())
        parsed = tomllib.loads(dumps_toml(d))
        assert spec_to_dict(spec_from_dict(parsed)) == d

    def test_file_round_trip_toml_and_json(self, tmp_path):
        spec = _full_spec()
        for name in ("campaign.toml", "campaign.json"):
            path = str(tmp_path / name)
            save_spec(spec, path)
            assert spec_to_dict(load_spec(path)) == spec_to_dict(spec)

    def test_task_decorator_metadata_survives(self):
        spec2 = spec_from_dict(spec_to_dict(_full_spec()))
        tds = {t.method: t for t in spec2.tasks}
        assert tds["special_task"].pool == "special"
        assert tds["special_task"].timeout_s == 2.5

    def test_bare_string_task_honors_decorator(self):
        spec = spec_from_dict({
            "tasks": ["test_config_launch.special_task"],
            "pools": {"special": 1},
        })
        td = spec.tasks[0]
        assert td.pool == "special" and td.timeout_s == 2.5

    def test_loaded_spec_actually_runs(self, tmp_path):
        path = str(tmp_path / "c.toml")
        save_spec(_full_spec(), path)
        app = ColmenaApp(load_spec(path))
        with app.run(timeout=30) as handle:
            assert handle.wait(30)
        assert handle.thinker.count == 4
        assert app.report.completed


class TestSpecVersioning:
    def test_to_dict_stamps_current_version(self):
        assert spec_to_dict(_full_spec())["version"] == SPEC_VERSION

    def test_saved_files_carry_the_version(self, tmp_path):
        path = str(tmp_path / "c.toml")
        save_spec(_full_spec(), path)
        assert f"version = {SPEC_VERSION}" in open(path).read()

    def test_v1_int_pool_shorthand_migrates(self):
        # a pre-versioning file: no version key, bare-int pool sizes
        spec = spec_from_dict({
            "tasks": ["test_config_launch.special_task"],
            "pools": {"special": 3},
        })
        assert spec.pools["special"].size == 3

    def test_v2_rejects_int_pool_shorthand(self):
        with pytest.raises(ValueError, match="bare-int shorthand"):
            spec_from_dict({
                "version": 2,
                "tasks": ["test_config_launch.special_task"],
                "pools": {"special": 3},
            })

    def test_future_version_fails_loudly(self):
        with pytest.raises(ValueError, match="upgrade repro"):
            spec_from_dict({
                "version": SPEC_VERSION + 1,
                "tasks": ["test_config_launch.special_task"],
            })

    @pytest.mark.parametrize("bad", ["2", True, 0, -1, 1.5])
    def test_malformed_version_rejected(self, bad):
        with pytest.raises(ValueError, match="version"):
            spec_from_dict({
                "version": bad,
                "tasks": ["test_config_launch.special_task"],
            })

    def test_versioned_file_load(self, tmp_path):
        # save (stamps v2) -> load honors the stamp and round-trips
        path = str(tmp_path / "c.json")
        save_spec(_full_spec(), path)
        doc = json.load(open(path))
        assert doc["version"] == SPEC_VERSION
        assert spec_to_dict(load_spec(path)) == spec_to_dict(_full_spec())

    def test_v1_file_still_loads(self, tmp_path):
        # a legacy file written before versioning existed
        path = str(tmp_path / "old.json")
        doc = spec_to_dict(_full_spec())
        del doc["version"]
        doc["pools"]["special"] = 1  # the old shorthand
        json.dump(doc, open(path, "w"))
        spec = load_spec(path)
        assert spec.pools["special"].size == 1


class TestDottedPaths:
    def test_import_dotted_resolves_nested_attr(self):
        assert import_dotted("repro.core.PoolSpec") is PoolSpec

    def test_import_dotted_bad_module(self):
        with pytest.raises(ImportError, match="no importable module prefix"):
            import_dotted("no_such_pkg_xyz.mod.fn")

    def test_import_dotted_bad_attr_names_the_culprit(self):
        with pytest.raises(ImportError, match="has no attribute 'nope'"):
            import_dotted("repro.core.nope")

    def test_broken_module_surfaces_its_real_error(self, tmp_path, monkeypatch):
        """A module that exists but fails to import must report its own
        error, not a misleading 'no attribute' fallback."""
        (tmp_path / "broken_cfg_mod.py").write_text("import no_such_dep_xyz\n")
        monkeypatch.syspath_prepend(str(tmp_path))
        with pytest.raises(ImportError, match="no_such_dep_xyz"):
            import_dotted("broken_cfg_mod.fn")

    def test_local_function_rejected_with_fix_hint(self):
        def local_fn(x):
            return x

        with pytest.raises(ValueError, match="local/lambda"):
            dotted_path(local_fn)

    def test_lambda_rejected(self):
        with pytest.raises(ValueError, match="local/lambda"):
            spec_to_dict(AppSpec(tasks={"f": lambda x: x}))

    def test_spec_from_dict_bad_task_path(self):
        with pytest.raises(ImportError, match="no importable module prefix"):
            spec_from_dict({"tasks": ["nowhere_at_all.fn"]})

    def test_unknown_sections_rejected(self):
        with pytest.raises(ValueError, match="unknown spec sections"):
            spec_from_dict({"tasks": ["test_config_launch.plain_task"], "poolz": {}})

    def test_unknown_queue_keys_rejected(self):
        with pytest.raises(ValueError, match=r"queues: unknown keys \['backand'\]"):
            spec_from_dict({
                "tasks": ["test_config_launch.plain_task"],
                "queues": {"backand": "pipe"},
            })

    def test_unknown_task_keys_rejected(self):
        """A typo like timeout= (for timeout_s=) must not silently drop
        the setting."""
        with pytest.raises(ValueError, match=r"unknown keys \['timeout'\]"):
            spec_from_dict({
                "tasks": [{"fn": "test_config_launch.plain_task", "timeout": 5}],
            })


class TestRefsAndSmoke:
    def test_ref_and_call_escapes(self):
        spec = spec_from_dict({
            "tasks": [{"fn": "test_config_launch.plain_task", "method": "double"}],
            "steering": {
                "thinker": "test_config_launch.ConfigThinker",
                "kwargs": {
                    "sentinel": {"$ref": "test_config_launch.SENTINEL"},
                    "target": {"$call": "builtins.int", "args": ["7"]},
                },
            },
        })
        assert spec.steering.kwargs["sentinel"] is SENTINEL
        assert spec.steering.kwargs["target"] == 7

    def test_ref_with_extra_keys_rejected(self):
        with pytest.raises(ValueError, match=r"\$ref takes no other keys"):
            spec_from_dict({
                "tasks": ["test_config_launch.plain_task"],
                "steering": {"thinker": "test_config_launch.ConfigThinker",
                             "kwargs": {"x": {"$ref": "os.sep", "junk": 1}}},
            })

    def test_unserializable_kwargs_point_to_escapes(self):
        spec = AppSpec(
            tasks={"double": plain_task},
            steering=SteeringSpec(ConfigThinker, dict(sentinel=object())),
        )
        with pytest.raises(ValueError, match=r"\$ref"):
            spec_to_dict(spec)

    def test_smoke_overrides_deep_merge(self, tmp_path):
        path = str(tmp_path / "c.toml")
        with open(path, "w") as f:
            f.write(
                '[[tasks]]\nfn = "test_config_launch.plain_task"\nmethod = "double"\n\n'
                + '[steering]\nthinker = "test_config_launch.ConfigThinker"\n'
                + '[steering.kwargs]\ntarget = 40\nn_parallel = 2\n\n'
                + '[smoke.steering.kwargs]\ntarget = 3\n'
            )
        full = load_spec(path)
        smoke = load_spec(path, smoke=True)
        assert full.steering.kwargs["target"] == 40
        assert smoke.steering.kwargs["target"] == 3
        assert smoke.steering.kwargs["n_parallel"] == 2  # merged, not replaced

    def test_smoke_flag_without_table_errors(self, tmp_path):
        path = str(tmp_path / "c.toml")
        with open(path, "w") as f:
            f.write('[[tasks]]\nfn = "test_config_launch.plain_task"\n')
        with pytest.raises(ValueError, match="no \\[smoke\\] table"):
            load_spec(path, smoke=True)


class TestConfigResume:
    def test_resume_through_config_file(self, tmp_path):
        """The checkpoint/resume path driven purely from a saved file."""
        state_dir = str(tmp_path / "state")
        cfg = str(tmp_path / "c.json")
        spec = AppSpec(
            tasks=[TaskDef(fn=plain_task, method="double")],
            pools={"default": 2},
            steering=SteeringSpec(ConfigThinker, dict(target=4)),
            campaign=CampaignSpec(state_dir=state_dir, checkpoint_interval_s=0.2),
        )
        save_spec(spec, cfg)

        first = ColmenaApp(load_spec(cfg))
        first.execute(timeout=30)
        assert first.thinker.count == 4
        assert first.report.checkpoints_written >= 1

        second_spec = load_spec(cfg)
        second_spec.steering.kwargs["target"] = 8
        second = ColmenaApp(second_spec)
        second.execute(timeout=30)
        assert second.report.resumed_from is not None
        assert second.thinker.count == 8


@pytest.mark.skipif(not os.path.isdir(os.path.join(REPO_ROOT, "examples")),
                    reason="examples/ not present")
class TestCLI:
    def _run_cli(self, *args, timeout=120):
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src") + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
        )
        return subprocess.run(
            [sys.executable, "-m", "repro.app", *args],
            cwd=REPO_ROOT, env=env, capture_output=True, text=True, timeout=timeout,
        )

    def test_run_quickstart_toml_smoke(self):
        proc = self._run_cli("run", "examples/quickstart.toml", "--smoke")
        assert proc.returncode == 0, proc.stderr
        assert "campaign,completed,1" in proc.stdout

    def test_show_is_diffable_json(self):
        proc = self._run_cli("show", "examples/quickstart.toml")
        assert proc.returncode == 0, proc.stderr
        d = json.loads(proc.stdout)
        assert d["steering"]["thinker"] == "examples.quickstart.Quickstart"
        assert d["pools"]["default"]["size"] == 4


class TestSpecDiff:
    """`python -m repro.app diff a.toml b.toml`: field-aware, version-
    stamp aware, with $ref/$call rendered readably."""

    A = """
version = 2
[[tasks]]
fn = "math.sin"
timeout_s = 5
[pools.default]
size = 4
[control]
weight = 2.0
"""
    B = """
version = 2
[[tasks]]
fn = "math.sin"
[[tasks]]
fn = "math.cos"
[pools.default]
size = 2
[control]
weight = 2.0
priority = 1
"""

    def test_diff_lines_are_field_aware(self):
        from repro.core.specfile import diff_spec_dicts
        import tomli

        lines = diff_spec_dicts(tomli.loads(self.A), tomli.loads(self.B))
        assert "~ pools.default.size: 4 -> 2" in lines
        assert "- tasks[math.sin].timeout_s = 5" in lines
        assert any(line.startswith("+ tasks[math.cos].fn") for line in lines)
        assert "+ control.priority = 1" in lines
        assert not any("weight" in line for line in lines)  # unchanged field

    def test_identical_specs_diff_empty(self):
        from repro.core.specfile import diff_spec_dicts
        import tomli

        assert diff_spec_dicts(tomli.loads(self.A), tomli.loads(self.A)) == []

    def test_cli_exit_codes_and_output(self, tmp_path):
        a = tmp_path / "a.toml"
        b = tmp_path / "b.toml"
        a.write_text(self.A)
        b.write_text(self.B)
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO_ROOT, "src"))
        run = subprocess.run(
            [sys.executable, "-m", "repro.app", "diff", str(a), str(b)],
            capture_output=True, text=True, env=env,
        )
        assert run.returncode == 1  # differences found
        assert "pools.default.size" in run.stdout
        same = subprocess.run(
            [sys.executable, "-m", "repro.app", "diff", str(a), str(a)],
            capture_output=True, text=True, env=env,
        )
        assert same.returncode == 0
        assert "equivalent" in same.stdout

    def test_version_migration_is_reported_not_diffed(self, tmp_path):
        """A v1 file (int pool shorthand) diffed against its v2 twin is
        equivalent apart from the version note."""
        from repro.core.specfile import diff_spec_dicts
        import tomli

        v1 = "version = 1\n[[tasks]]\nfn = \"math.sin\"\n[pools]\ndefault = 4\n"
        v2 = "version = 2\n[[tasks]]\nfn = \"math.sin\"\n[pools.default]\nsize = 4\n"
        lines = diff_spec_dicts(tomli.loads(v1), tomli.loads(v2))
        assert lines and lines[0].startswith("~ version: 1 -> 2")
        assert len(lines) == 1  # migrated bodies agree
